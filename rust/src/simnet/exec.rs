//! Bounded-concurrency execution for big simulated worlds.
//!
//! [`crate::mpisim::World::run`] gives every rank its own OS thread — the
//! only shape under which arbitrary blocking SPMD closures (barriers,
//! matched receives, flush spins) compose without a coroutine runtime. At
//! 4–32 ranks that is free; at 1024–4096 ranks the *scheduler* becomes the
//! bottleneck: thousands of spin-yielding threads thrash the run queue and
//! every modelled microsecond of wait costs a full context-switch storm.
//!
//! The pooled execution mode bounds that. A [`RunGate`] is a counting
//! semaphore of **run slots**: every rank thread still exists (its stack
//! holds its blocked SPMD state — that cannot be multiplexed away), but at
//! most `limit` of them are *runnable* at any instant; the rest are parked
//! in the kernel on a condvar, costing no CPU. Three cooperation points
//! keep the gate deadlock-free:
//!
//! - [`coop_yield`] — every spin-wait loop in the simulator routes through
//!   this instead of `std::thread::yield_now`. If other threads are parked
//!   waiting for a slot, the caller hands its slot over (FIFO-ish via a
//!   reserved hand-off, so spinners cannot starve parked waiters) and
//!   re-queues; otherwise it is a plain yield.
//! - [`blocking`] — wraps every *kernel* block (condvar waits in the
//!   mailbox and the passive-target lock queue): the slot is released for
//!   the duration of the wait and re-acquired on wake-up. A thread parked
//!   on a condvar holds no slot, so slot-holders can always run and wake
//!   it — no circular wait through the gate is possible.
//! - the slot itself is held only while the rank is genuinely runnable.
//!
//! The gate is advisory scheduling, not semantics: all rank interleavings
//! it admits are interleavings the thread-per-rank mode could also produce,
//! so results are bit-identical across execution modes (asserted by the
//! scale smoke test).

use std::cell::RefCell;
use std::sync::{Arc, Condvar, Mutex};

/// Counting run-slot semaphore with parked-waiter hand-off (see module
/// docs). One per pooled [`crate::mpisim::World::run`].
pub struct RunGate {
    limit: usize,
    st: Mutex<GateSt>,
    cv: Condvar,
}

#[derive(Default)]
struct GateSt {
    /// Slots currently held by runnable threads.
    active: usize,
    /// High-water mark of `active` (what the scale smoke test asserts).
    peak: usize,
    /// Threads parked in `acquire`.
    waiters: usize,
    /// Slots released *to* a parked waiter and reserved for one: a freshly
    /// arriving thread may not steal them, which is what prevents spinning
    /// slot-holders from starving parked ranks.
    handoff: usize,
}

impl RunGate {
    /// A gate admitting at most `limit` concurrently runnable threads.
    pub fn new(limit: usize) -> Self {
        RunGate { limit: limit.max(1), st: Mutex::new(GateSt::default()), cv: Condvar::new() }
    }

    /// The slot bound.
    pub fn limit(&self) -> usize {
        self.limit
    }

    /// High-water mark of concurrently runnable (slot-holding) threads.
    pub fn peak_active(&self) -> usize {
        self.st.lock().unwrap().peak
    }

    fn acquire(&self) {
        let mut st = self.st.lock().unwrap();
        if st.handoff == 0 && st.active < self.limit {
            st.active += 1;
            st.peak = st.peak.max(st.active);
            return;
        }
        st.waiters += 1;
        loop {
            st = self.cv.wait(st).unwrap();
            if st.handoff > 0 {
                st.handoff -= 1;
                st.waiters -= 1;
                st.active += 1;
                st.peak = st.peak.max(st.active);
                return;
            }
        }
    }

    fn release(&self) {
        let mut st = self.st.lock().unwrap();
        st.active -= 1;
        if st.waiters > st.handoff {
            // Reserve the slot for one parked waiter and wake it.
            st.handoff += 1;
            self.cv.notify_one();
        }
    }

    /// Are any threads parked waiting for a slot? (Cheap rotation check.)
    fn has_waiters(&self) -> bool {
        self.st.lock().unwrap().waiters > 0
    }
}

thread_local! {
    /// The gate of the pooled world this thread is a rank of, if any.
    static GATE: RefCell<Option<Arc<RunGate>>> = const { RefCell::new(None) };
}

/// RAII registration of the current thread as a gated rank: installs the
/// gate in thread-local storage and acquires a run slot; the drop releases
/// the slot and uninstalls the gate.
pub struct GateGuard {
    gate: Arc<RunGate>,
}

/// Register the current thread with `gate` and acquire its first run slot.
pub fn enter(gate: Arc<RunGate>) -> GateGuard {
    gate.acquire();
    GATE.with(|g| *g.borrow_mut() = Some(gate.clone()));
    GateGuard { gate }
}

impl Drop for GateGuard {
    fn drop(&mut self) {
        GATE.with(|g| *g.borrow_mut() = None);
        self.gate.release();
    }
}

fn current_gate() -> Option<Arc<RunGate>> {
    GATE.with(|g| g.borrow().clone())
}

/// Cooperative yield point for spin-wait loops. On an ungated thread
/// (thread-per-rank mode, the progress service) this is a plain
/// `yield_now`; on a gated rank it additionally hands the run slot to a
/// parked waiter when one exists.
#[inline]
pub fn coop_yield() {
    if let Some(gate) = current_gate() {
        if gate.has_waiters() {
            gate.release();
            std::thread::yield_now();
            gate.acquire();
            return;
        }
    }
    std::thread::yield_now();
}

/// Run `f` — a call that may park this thread in the kernel (condvar wait)
/// — with the run slot released for the duration. Ungated threads just run
/// `f`. Every kernel-blocking primitive of the simulator (mailbox matching,
/// passive-target lock queues) is wrapped in this, which is what makes the
/// gate deadlock-free: a parked thread never holds a slot.
#[inline]
pub fn blocking<R>(f: impl FnOnce() -> R) -> R {
    match current_gate() {
        None => f(),
        Some(gate) => {
            gate.release();
            let r = f();
            gate.acquire();
            r
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn gate_bounds_concurrency() {
        let gate = Arc::new(RunGate::new(2));
        let live = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let gate = gate.clone();
            let live = live.clone();
            handles.push(std::thread::spawn(move || {
                let _g = enter(gate);
                let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                assert!(now <= 2, "gate admitted {now} > 2 threads");
                std::thread::sleep(Duration::from_millis(2));
                live.fetch_sub(1, Ordering::SeqCst);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(gate.peak_active() <= 2);
        assert_eq!(gate.st.lock().unwrap().active, 0);
    }

    #[test]
    fn blocking_releases_slot() {
        // One slot, two threads: A parks inside `blocking` on a condvar
        // that only B (needing the slot) can signal. Without the release
        // this deadlocks.
        let gate = Arc::new(RunGate::new(1));
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let a = {
            let gate = gate.clone();
            let pair = pair.clone();
            std::thread::spawn(move || {
                let _g = enter(gate);
                blocking(|| {
                    let (m, cv) = &*pair;
                    let mut done = m.lock().unwrap();
                    while !*done {
                        done = cv.wait(done).unwrap();
                    }
                });
            })
        };
        let b = {
            let gate = gate.clone();
            let pair = pair.clone();
            std::thread::spawn(move || {
                let _g = enter(gate);
                let (m, cv) = &*pair;
                *m.lock().unwrap() = true;
                cv.notify_all();
            })
        };
        a.join().unwrap();
        b.join().unwrap();
        assert_eq!(gate.peak_active(), 1);
    }

    #[test]
    fn coop_yield_rotates_to_waiters() {
        // One slot: the holder spins in coop_yield; the waiter must still
        // get the slot (hand-off beats barging).
        let gate = Arc::new(RunGate::new(1));
        let won = Arc::new(AtomicUsize::new(0));
        let spinner = {
            let gate = gate.clone();
            let won = won.clone();
            std::thread::spawn(move || {
                let _g = enter(gate);
                while won.load(Ordering::SeqCst) == 0 {
                    coop_yield();
                }
            })
        };
        let waiter = {
            let gate = gate.clone();
            let won = won.clone();
            std::thread::spawn(move || {
                let _g = enter(gate);
                won.store(1, Ordering::SeqCst);
            })
        };
        waiter.join().unwrap();
        spinner.join().unwrap();
        assert_eq!(gate.peak_active(), 1);
    }

    #[test]
    fn ungated_threads_pass_through() {
        // No TLS gate installed: both helpers are plain calls.
        coop_yield();
        assert_eq!(blocking(|| 42), 42);
    }
}
