//! Deterministic fault injection for the simulated runtime (DST).
//!
//! Simulation-first is this repo's superpower: because the network is a
//! *model* ([`crate::simnet::CostModel`] + the channel table in
//! [`crate::mpisim::WorldState`]), adversity can be injected exactly where
//! real clusters produce it — and, unlike on real clusters, every injected
//! event can be a **pure function of a single `u64` seed**, so any failure
//! reproduces from its seed alone (TigerBeetle/FoundationDB-style
//! deterministic simulation testing).
//!
//! A [`FaultPlan`] describes four fault classes:
//!
//! 1. **Per-message latency jitter** and **per-channel slowdowns** — a
//!    seeded fraction of messages pay extra wire time, and a seeded subset
//!    of directed rank-pair channels is persistently slow (hot cable, bad
//!    NIC queue). Injected in the channel model's single choke point,
//!    `WorldState::book_transfer_after`, so window RMA, p2p sends, dynamic
//!    windows and the nonblocking-collective schedules are all covered.
//! 2. **Reordering of unordered RMA completions** — a seeded fraction of
//!    deferred-completion registrations is held back, so later-issued
//!    operations retire *before* earlier ones in the progress shards —
//!    exactly the out-of-order completion MPI-3's unordered RMA permits
//!    and `flush` must nonetheless cover.
//! 3. **Starved progress ticks** — a seeded fraction of engine wakeups
//!    fires but retires nothing and stalls for a modelled pause: the
//!    progress-starvation regime that motivated the asynchronous-progress
//!    follow-up work (arXiv:1609.08574).
//! 4. **Straggler nodes** — every transfer touching a seeded-chosen node
//!    runs at a configurable slowdown factor (one slow machine in the
//!    job, the classic adverse placement).
//!
//! Every decision is derived by hashing `(seed, fault class, stable key,
//! per-key sequence number)` through the splitmix64 finalizer — never from
//! wall-clock state — and every *injected* event is counted (and, for the
//! dynamic classes, traced as a [`FaultEvent`]) so tests can assert the
//! plan actually fired and that a seed replays to an identical trace.
//!
//! Injected delays are **absolute modelled nanoseconds, not scaled by**
//! [`crate::simnet::CostModel::scale`]: a fault plan stays adversarial
//! over `CostModel::zero()`, which is what lets the chaos suite sweep
//! 50+ seeds in wall-clock seconds.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// splitmix64 finalizer — the one-way mix every fault decision goes
/// through (same core as [`crate::testing::prop::Rng`]).
#[inline]
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Domain-separation constants, one per decision class.
const CLASS_JITTER: u64 = 0x4A17;
const CLASS_SLOW_CHANNEL: u64 = 0x510C;
const CLASS_REORDER: u64 = 0x2E02;
const CLASS_STARVE: u64 = 0x57A2;
const CLASS_STRAGGLER: u64 = 0x5742;
const CLASS_KNOB: u64 = 0x6B0B;

/// A seeded fault-injection plan: which hazards are live and how hard
/// they hit. Plain data — construct with [`FaultPlan::from_seed`] (all
/// classes on, seed-derived intensities) or [`FaultPlan::quiet`] (all
/// off) and override fields with struct-update syntax:
///
/// ```
/// use dart::simnet::FaultPlan;
/// let stragglers_only = FaultPlan {
///     straggler_nodes: 1,
///     straggler_factor: 3.0,
///     ..FaultPlan::quiet(42)
/// };
/// assert!(stragglers_only.jitter_ns(0, 0).is_none());
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    /// The reproduction handle: every decision this plan makes is a pure
    /// function of this seed and the event's stable key.
    pub seed: u64,
    /// Probability (per mille) that a message on any channel pays jitter.
    pub jitter_per_mille: u32,
    /// Maximum extra modelled wire nanoseconds one jittered message pays
    /// (the actual amount is seed-drawn in `[1, max]`).
    pub jitter_max_ns: u64,
    /// Probability (per mille) that a directed rank-pair channel is
    /// *persistently* slow for the whole run.
    pub slow_channel_per_mille: u32,
    /// Multiplier applied to the modelled serialization + latency of
    /// every message on a slow channel.
    pub slow_channel_factor: f64,
    /// Probability (per mille) that one deferred-RMA registration is held
    /// back past its modelled completion (completion reordering).
    pub reorder_per_mille: u32,
    /// Maximum hold-back in modelled nanoseconds (seed-drawn `[1, max]`).
    pub reorder_max_ns: u64,
    /// Probability (per mille) that a progress-engine tick fires but
    /// retires nothing.
    pub starve_per_mille: u32,
    /// Modelled nanoseconds a starved tick stalls before returning.
    pub starve_stall_ns: u64,
    /// How many nodes of the topology run slow (capped to `nodes - 1` so
    /// at least one node stays healthy; 0 disables the class).
    pub straggler_nodes: usize,
    /// Slowdown multiplier for every transfer touching a straggler node.
    pub straggler_factor: f64,
}

impl FaultPlan {
    /// A plan with **every class live** at seed-derived intensities —
    /// probabilities land in ranges that make each class fire within a
    /// few dozen events, so a 50-seed sweep demonstrably exercises all
    /// four hazards.
    pub fn from_seed(seed: u64) -> Self {
        let knob = |i: u64, lo: u64, span: u64| lo + mix(seed ^ mix(CLASS_KNOB ^ i)) % span;
        FaultPlan {
            seed,
            jitter_per_mille: knob(1, 120, 380) as u32,
            jitter_max_ns: knob(2, 2_000, 30_000),
            slow_channel_per_mille: knob(3, 150, 350) as u32,
            slow_channel_factor: 2.0 + knob(4, 0, 30) as f64 / 10.0,
            reorder_per_mille: knob(5, 150, 400) as u32,
            reorder_max_ns: knob(6, 5_000, 60_000),
            starve_per_mille: knob(7, 120, 280) as u32,
            starve_stall_ns: knob(8, 500, 4_500),
            straggler_nodes: 1,
            straggler_factor: 2.0 + knob(9, 0, 60) as f64 / 10.0,
        }
    }

    /// A plan with **every class off** — the base for struct-update
    /// construction of single-hazard plans (see the type-level example).
    pub fn quiet(seed: u64) -> Self {
        FaultPlan {
            seed,
            jitter_per_mille: 0,
            jitter_max_ns: 0,
            slow_channel_per_mille: 0,
            slow_channel_factor: 1.0,
            reorder_per_mille: 0,
            reorder_max_ns: 0,
            starve_per_mille: 0,
            starve_stall_ns: 0,
            straggler_nodes: 0,
            straggler_factor: 1.0,
        }
    }

    /// One seeded draw for `(class, key, seq)`.
    #[inline]
    fn draw(&self, class: u64, key: u64, seq: u64) -> u64 {
        mix(self.seed ^ mix(class) ^ mix(key).rotate_left(23) ^ mix(seq).rotate_left(47))
    }

    /// Does `(class, key, seq)` fire at `per_mille` probability?
    #[inline]
    fn fires(&self, class: u64, key: u64, seq: u64, per_mille: u32) -> bool {
        per_mille > 0 && self.draw(class, key, seq) % 1000 < u64::from(per_mille)
    }

    /// Extra wire nanoseconds the `msg_seq`-th message on `channel_key`
    /// pays, or `None` if that message is clean. Pure in
    /// `(seed, channel_key, msg_seq)`.
    pub fn jitter_ns(&self, channel_key: u64, msg_seq: u64) -> Option<u64> {
        if !self.fires(CLASS_JITTER, channel_key, msg_seq, self.jitter_per_mille) {
            return None;
        }
        Some(1 + self.draw(CLASS_JITTER ^ 1, channel_key, msg_seq) % self.jitter_max_ns.max(1))
    }

    /// The persistent slowdown factor of `channel_key`, or `None` for a
    /// healthy channel. Pure in `(seed, channel_key)`.
    pub fn channel_slowdown(&self, channel_key: u64) -> Option<f64> {
        self.fires(CLASS_SLOW_CHANNEL, channel_key, 0, self.slow_channel_per_mille)
            .then_some(self.slow_channel_factor)
    }

    /// Modelled nanoseconds the `reg_seq`-th deferred-RMA registration of
    /// `origin` is held back past its wire completion, or `None`. A hit
    /// makes later-issued operations retire first — the MPI-3 unordered-
    /// completion hazard. Pure in `(seed, origin, reg_seq)`.
    pub fn reorder_hold_ns(&self, origin: u64, reg_seq: u64) -> Option<u64> {
        if !self.fires(CLASS_REORDER, origin, reg_seq, self.reorder_per_mille) {
            return None;
        }
        Some(1 + self.draw(CLASS_REORDER ^ 1, origin, reg_seq) % self.reorder_max_ns.max(1))
    }

    /// Is the `tick_seq`-th engine wakeup starved (fires but retires
    /// nothing)? Pure in `(seed, tick_seq)`.
    pub fn starves_tick(&self, tick_seq: u64) -> bool {
        self.fires(CLASS_STARVE, tick_seq, 0, self.starve_per_mille)
    }

    /// The straggler verdict for every node of an `nodes`-node topology:
    /// the `min(straggler_nodes, nodes - 1)` nodes with the smallest
    /// seeded hash are slow — exact count, at least one healthy node.
    /// Pure in `(seed, nodes)`.
    pub fn straggler_set(&self, nodes: usize) -> Vec<bool> {
        let k = self.straggler_nodes.min(nodes.saturating_sub(1));
        let mut flags = vec![false; nodes];
        if k == 0 {
            return flags;
        }
        let mut ranked: Vec<usize> = (0..nodes).collect();
        ranked.sort_by_key(|&n| self.draw(CLASS_STRAGGLER, n as u64, 0));
        for &n in ranked.iter().take(k) {
            flags[n] = true;
        }
        flags
    }
}

/// One dynamic injected event, as recorded in the world's fault trace.
///
/// The trace holds only the *dynamic* classes (jitter, reorder, starved
/// tick) — slow channels and stragglers are static facts of the plan,
/// queryable via [`FaultPlan::channel_slowdown`] /
/// [`FaultPlan::straggler_set`] and counted in [`FaultStats`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct FaultEvent {
    /// Which hazard fired.
    pub kind: FaultKind,
    /// The stable key (channel key for jitter, origin rank for reorder,
    /// 0 for starved ticks).
    pub key: u64,
    /// The per-key sequence number (message seq, registration seq, or the
    /// global tick index).
    pub seq: u64,
    /// Injected magnitude in modelled nanoseconds (0 for starved ticks
    /// with no stall configured).
    pub magnitude_ns: u64,
}

/// The dynamic fault classes a [`FaultEvent`] can record.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum FaultKind {
    /// Per-message latency jitter on a channel.
    Jitter,
    /// A deferred-RMA completion held back (reordered).
    Reorder,
    /// A progress tick that fired but retired nothing.
    StarvedTick,
}

/// Snapshot of the world-global injected-event counters — what tests
/// assert against to prove the plan fired.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Messages that paid per-message jitter.
    pub jitter_events: u64,
    /// Messages that rode a persistently slow channel.
    pub slow_channel_msgs: u64,
    /// Messages with at least one endpoint on a straggler node.
    pub straggler_msgs: u64,
    /// Deferred-RMA registrations held back (completion reorderings).
    pub reorders: u64,
    /// Progress ticks that fired but retired nothing.
    pub starved_ticks: u64,
}

impl FaultStats {
    /// Injected events across all classes.
    pub fn total(&self) -> u64 {
        self.jitter_events
            + self.slow_channel_msgs
            + self.straggler_msgs
            + self.reorders
            + self.starved_ticks
    }
}

impl std::ops::AddAssign for FaultStats {
    fn add_assign(&mut self, o: FaultStats) {
        self.jitter_events += o.jitter_events;
        self.slow_channel_msgs += o.slow_channel_msgs;
        self.straggler_msgs += o.straggler_msgs;
        self.reorders += o.reorders;
        self.starved_ticks += o.starved_ticks;
    }
}

/// Cap on recorded trace events — a backstop so a long bench run with
/// faults on cannot grow the trace without bound (counters keep counting
/// past the cap; only recording stops).
const TRACE_CAP: usize = 1 << 16;

/// Per-world live fault state: the plan, the resolved straggler set, the
/// injected-event counters and the event trace. One per
/// [`crate::mpisim::WorldState`] when a plan is configured.
pub(crate) struct FaultState {
    pub(crate) plan: FaultPlan,
    /// Straggler verdict per node, resolved once at world creation.
    straggler: Vec<bool>,
    jitter_events: AtomicU64,
    slow_channel_msgs: AtomicU64,
    straggler_msgs: AtomicU64,
    reorders: AtomicU64,
    starved_ticks: AtomicU64,
    trace: Mutex<Vec<FaultEvent>>,
}

impl FaultState {
    pub(crate) fn new(plan: FaultPlan, nodes: usize) -> Self {
        FaultState {
            straggler: plan.straggler_set(nodes),
            plan,
            jitter_events: AtomicU64::new(0),
            slow_channel_msgs: AtomicU64::new(0),
            straggler_msgs: AtomicU64::new(0),
            reorders: AtomicU64::new(0),
            starved_ticks: AtomicU64::new(0),
            trace: Mutex::new(Vec::new()),
        }
    }

    /// Is `node` one of the plan's stragglers?
    #[inline]
    pub(crate) fn is_straggler(&self, node: usize) -> bool {
        self.straggler.get(node).copied().unwrap_or(false)
    }

    fn record(&self, kind: FaultKind, key: u64, seq: u64, magnitude_ns: u64) {
        let mut t = self.trace.lock().unwrap();
        if t.len() < TRACE_CAP {
            t.push(FaultEvent { kind, key, seq, magnitude_ns });
        }
    }

    pub(crate) fn note_jitter(&self, channel_key: u64, msg_seq: u64, ns: u64) {
        self.jitter_events.fetch_add(1, Ordering::Relaxed);
        self.record(FaultKind::Jitter, channel_key, msg_seq, ns);
    }

    pub(crate) fn note_slow_channel_msg(&self) {
        self.slow_channel_msgs.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn note_straggler_msg(&self) {
        self.straggler_msgs.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn note_reorder(&self, origin: u64, reg_seq: u64, ns: u64) {
        self.reorders.fetch_add(1, Ordering::Relaxed);
        self.record(FaultKind::Reorder, origin, reg_seq, ns);
    }

    pub(crate) fn note_starved_tick(&self, tick_seq: u64, stall_ns: u64) {
        self.starved_ticks.fetch_add(1, Ordering::Relaxed);
        self.record(FaultKind::StarvedTick, 0, tick_seq, stall_ns);
    }

    /// Counter snapshot (monotonic; safe to diff).
    pub(crate) fn snapshot(&self) -> FaultStats {
        FaultStats {
            jitter_events: self.jitter_events.load(Ordering::Relaxed),
            slow_channel_msgs: self.slow_channel_msgs.load(Ordering::Relaxed),
            straggler_msgs: self.straggler_msgs.load(Ordering::Relaxed),
            reorders: self.reorders.load(Ordering::Relaxed),
            starved_ticks: self.starved_ticks.load(Ordering::Relaxed),
        }
    }

    /// The recorded dynamic events in **canonical order** (sorted by
    /// class/key/seq) — cross-thread push order is scheduling-dependent,
    /// so traces are compared after sorting. Two runs of the same seeded
    /// scenario must produce identical canonical traces.
    pub(crate) fn trace(&self) -> Vec<FaultEvent> {
        let mut t = self.trace.lock().unwrap().clone();
        t.sort_unstable();
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decisions_are_pure_functions_of_the_seed() {
        let a = FaultPlan::from_seed(0xDEAD_BEEF);
        let b = FaultPlan::from_seed(0xDEAD_BEEF);
        assert_eq!(a, b);
        for key in 0..50u64 {
            for seq in 0..20u64 {
                assert_eq!(a.jitter_ns(key, seq), b.jitter_ns(key, seq));
                assert_eq!(a.reorder_hold_ns(key, seq), b.reorder_hold_ns(key, seq));
            }
            assert_eq!(a.channel_slowdown(key), b.channel_slowdown(key));
            assert_eq!(a.starves_tick(key), b.starves_tick(key));
        }
        assert_eq!(a.straggler_set(7), b.straggler_set(7));
    }

    #[test]
    fn different_seeds_differ_somewhere() {
        let a = FaultPlan::from_seed(1);
        let b = FaultPlan::from_seed(2);
        let differs = (0..200u64).any(|k| a.jitter_ns(k, 0) != b.jitter_ns(k, 0));
        assert!(differs, "two seeds produced identical jitter streams");
    }

    #[test]
    fn from_seed_fires_every_class_in_bounded_draws() {
        for seed in [0u64, 1, 42, 0xFFFF_FFFF_FFFF_FFFF] {
            let p = FaultPlan::from_seed(seed);
            assert!((0..500).any(|s| p.jitter_ns(3, s).is_some()), "jitter dead at {seed}");
            assert!((0..500).any(|k| p.channel_slowdown(k).is_some()), "slow dead at {seed}");
            assert!((0..500).any(|s| p.reorder_hold_ns(1, s).is_some()), "reorder dead at {seed}");
            assert!((0..500).any(|t| p.starves_tick(t)), "starve dead at {seed}");
            assert!(p.jitter_max_ns > 0 && p.straggler_factor > 1.0);
        }
    }

    #[test]
    fn quiet_plan_never_fires() {
        let p = FaultPlan::quiet(7);
        assert!((0..1000u64).all(|s| p.jitter_ns(s, s).is_none()));
        assert!((0..1000u64).all(|k| p.channel_slowdown(k).is_none()));
        assert!((0..1000u64).all(|s| p.reorder_hold_ns(0, s).is_none()));
        assert!((0..1000u64).all(|t| !p.starves_tick(t)));
        assert!(p.straggler_set(8).iter().all(|&b| !b));
    }

    #[test]
    fn straggler_set_is_exact_and_leaves_a_healthy_node() {
        let p = FaultPlan { straggler_nodes: 3, ..FaultPlan::from_seed(11) };
        for nodes in 1..10 {
            let set = p.straggler_set(nodes);
            let count = set.iter().filter(|&&b| b).count();
            assert_eq!(count, 3.min(nodes.saturating_sub(1)), "nodes={nodes}");
        }
    }

    #[test]
    fn state_counts_and_traces_canonically() {
        let st = FaultState::new(FaultPlan::from_seed(5), 4);
        st.note_reorder(1, 9, 100);
        st.note_jitter(7, 0, 50);
        st.note_starved_tick(3, 0);
        st.note_slow_channel_msg();
        st.note_straggler_msg();
        let s = st.snapshot();
        assert_eq!(
            (s.jitter_events, s.reorders, s.starved_ticks, s.slow_channel_msgs, s.straggler_msgs),
            (1, 1, 1, 1, 1)
        );
        assert_eq!(s.total(), 5);
        // Canonical order: Jitter < Reorder < StarvedTick regardless of
        // push order.
        let kinds: Vec<FaultKind> = st.trace().iter().map(|e| e.kind).collect();
        assert_eq!(kinds, vec![FaultKind::Jitter, FaultKind::Reorder, FaultKind::StarvedTick]);
    }
}
