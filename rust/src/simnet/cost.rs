//! Network cost model standing in for the Cray XE6 / Gemini testbed.
//!
//! The model is deliberately simple — the paper's analysis (§V-C) fits the
//! data to `t(m) = latency + m / bandwidth` per placement tier, with a
//! protocol change on top: Cray MPICH switches from **eager E0** (no copy)
//! to **eager E1** (data copied through internal MPI buffers on both the
//! send and the receive side) for messages larger than 4 KiB, which is
//! visible as a jump in the DTCT between 4 KiB and 8 KiB (Figs. 8/9) and as
//! a bandwidth dip around 8 KiB (Fig. 15).
//!
//! [`CostModel::inject`] spins for the modelled duration; it is called from
//! the [`crate::mpisim`] transport on every message/RMA transfer, equally
//! for raw-MPI and DART traffic, so the *difference* between the two — the
//! paper's metric — remains the genuine software overhead of the DART layer.

use super::Tier;
use std::time::{Duration, Instant};

/// Cray MPICH eager protocol variants (paper §V-C).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Protocol {
    /// No intermediate copy; message ≤ 4 KiB.
    EagerE0,
    /// Data copied into internal MPI buffers on both sides; message > 4 KiB.
    EagerE1,
}

/// Linear cost parameters for one placement tier.
#[derive(Debug, Clone, Copy)]
pub struct TierCost {
    /// Base one-way latency in nanoseconds.
    pub latency_ns: f64,
    /// Sustained bandwidth in bytes per nanosecond (= GB/s).
    pub bytes_per_ns: f64,
}

impl TierCost {
    /// Pure linear transfer time for `bytes`.
    #[inline]
    pub fn transfer_ns(&self, bytes: usize) -> f64 {
        self.latency_ns + bytes as f64 / self.bytes_per_ns
    }
}

/// Tiered network cost model with the E0/E1 eager-protocol switch.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Per-tier linear cost (indexed by [`Tier`] order: intra-NUMA,
    /// inter-NUMA, inter-node).
    pub tiers: [TierCost; 3],
    /// Messages strictly larger than this use protocol E1 (paper: 4 KiB).
    pub eager_e0_limit: usize,
    /// Extra fixed cost of entering the E1 path (buffer management, both
    /// sides), nanoseconds.
    pub e1_latency_ns: f64,
    /// Copy bandwidth of the E1 bounce buffers, bytes/ns; the copy is paid
    /// twice (send side + receive side).
    pub e1_copy_bytes_per_ns: f64,
    /// Fixed per-*message* protocol cost (header processing, matching, DMA
    /// descriptor setup) that occupies the channel's serialization stage —
    /// unlike the tier latency, which pipelines. This is what makes one
    /// vector-typed transfer of `n × block` bytes cheaper than `n`
    /// back-to-back block transfers: the bandwidth term is identical, but
    /// the per-message overhead is paid once instead of `n` times.
    pub msg_overhead_ns: f64,
    /// CPU cost of one asynchronous-progress wakeup: every tick of the
    /// progress engine ([`crate::mpisim::ProgressMode`]) — a dedicated
    /// progress thread's wakeup or a caller's cooperative poll — charges
    /// this many nanoseconds, modelling the cycles the service steals from
    /// computation (cf. Zhou & Gracia, "Asynchronous progress design for a
    /// MPI-based PGAS one-sided communication system"). This is what makes
    /// the Caller-vs-Thread-vs-Polling ablation a real trade-off: more
    /// wakeups buy more overlap but cost more stolen CPU time.
    pub progress_tick_ns: f64,
    /// Global multiplier on injected time. `0.0` disables injection (used by
    /// unit tests and by pure-software-overhead measurements).
    pub scale: f64,
}

impl CostModel {
    /// Calibration that reproduces the *shape* of the Hermit measurements:
    /// sub-microsecond intra-node latencies, ~1.5 µs inter-node, a visible
    /// jump at the 4 KiB → 8 KiB transition, and single-digit GB/s
    /// bandwidth, ordered intra-NUMA > inter-NUMA > inter-node.
    pub fn hermit() -> Self {
        CostModel {
            tiers: [
                // intra-NUMA: shared L3 / local memory controller
                TierCost { latency_ns: 350.0, bytes_per_ns: 10.0 },
                // inter-NUMA: HyperTransport hop between dies/sockets
                TierCost { latency_ns: 750.0, bytes_per_ns: 8.0 },
                // inter-node: Gemini interconnect
                TierCost { latency_ns: 1400.0, bytes_per_ns: 5.5 },
            ],
            eager_e0_limit: 4 * 1024,
            e1_latency_ns: 900.0,
            e1_copy_bytes_per_ns: 9.0,
            msg_overhead_ns: 60.0,
            progress_tick_ns: 120.0,
            scale: 1.0,
        }
    }

    /// A model that injects nothing — transfers cost only the real memcpy.
    /// Used by unit tests and by overhead-isolation benches.
    pub fn zero() -> Self {
        let mut m = Self::hermit();
        m.scale = 0.0;
        m
    }

    /// Which eager protocol a message of `bytes` uses.
    #[inline]
    pub fn protocol(&self, bytes: usize) -> Protocol {
        if bytes > self.eager_e0_limit {
            Protocol::EagerE1
        } else {
            Protocol::EagerE0
        }
    }

    /// Modelled wire time for a `bytes`-sized transfer on `tier`, in ns
    /// (before the global `scale` factor).
    pub fn transfer_ns(&self, tier: Tier, bytes: usize) -> f64 {
        let t = self.tiers[tier as usize].transfer_ns(bytes);
        match self.protocol(bytes) {
            Protocol::EagerE0 => t,
            Protocol::EagerE1 => {
                // Copy through internal buffers on both sides.
                t + self.e1_latency_ns + 2.0 * bytes as f64 / self.e1_copy_bytes_per_ns
            }
        }
    }

    /// Spin for the modelled duration of a transfer. No-op when `scale == 0`.
    #[inline]
    pub fn inject(&self, tier: Tier, bytes: usize) {
        if self.scale <= 0.0 {
            return;
        }
        let ns = self.transfer_ns(tier, bytes) * self.scale;
        spin_for(Duration::from_nanos(ns as u64));
    }
}

/// Wait with nanosecond-ish precision. `thread::sleep` has ~50 µs
/// granularity on Linux, far above the sub-µs latencies we model, so short
/// waits spin (the paper's MPI does the same while polling the NIC).
/// Longer waits yield the CPU between polls: the simulation timeshares
/// many rank-threads over few (possibly one) physical cores, and a pure
/// spin would stall every other rank for a full scheduler quantum. Under
/// pooled execution ([`crate::simnet::exec`]) the yield additionally hands
/// the caller's run slot to a parked rank, so thousand-rank worlds never
/// have more than the slot bound spinning at once.
#[inline]
pub fn spin_for(d: Duration) {
    const SPIN_ONLY: Duration = Duration::from_micros(5);
    let start = Instant::now();
    loop {
        let e = start.elapsed();
        if e >= d {
            return;
        }
        if d - e > SPIN_ONLY {
            super::exec::coop_yield();
        } else {
            std::hint::spin_loop();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn protocol_switches_at_4k() {
        let m = CostModel::hermit();
        assert_eq!(m.protocol(4096), Protocol::EagerE0);
        assert_eq!(m.protocol(4097), Protocol::EagerE1);
        assert_eq!(m.protocol(1), Protocol::EagerE0);
    }

    #[test]
    fn e1_jump_is_visible() {
        // The modelled DTCT must jump by more than the pure linear growth
        // between 4 KiB and 8 KiB — this is the paper's Figs 8/9 feature.
        let m = CostModel::hermit();
        for tier in Tier::ALL {
            let t4 = m.transfer_ns(tier, 4096);
            let t8 = m.transfer_ns(tier, 8192);
            let linear_growth = 4096.0 / m.tiers[tier as usize].bytes_per_ns;
            assert!(
                t8 - t4 > linear_growth + m.e1_latency_ns * 0.9,
                "no E1 jump on {tier}: t4={t4} t8={t8}"
            );
        }
    }

    #[test]
    fn tiers_are_ordered() {
        let m = CostModel::hermit();
        for bytes in [1usize, 512, 65536, 1 << 21] {
            let t = |tier| m.transfer_ns(tier, bytes);
            assert!(t(Tier::IntraNuma) < t(Tier::InterNuma));
            assert!(t(Tier::InterNuma) < t(Tier::InterNode));
        }
    }

    #[test]
    fn zero_model_injects_nothing() {
        let m = CostModel::zero();
        let start = Instant::now();
        for _ in 0..1000 {
            m.inject(Tier::InterNode, 1 << 21);
        }
        assert!(start.elapsed() < Duration::from_millis(50));
    }

    #[test]
    fn spin_for_has_reasonable_precision() {
        let d = Duration::from_micros(200);
        let start = Instant::now();
        spin_for(d);
        let e = start.elapsed();
        assert!(e >= d);
        assert!(e < d * 4, "spin overshoot: {e:?}");
    }
}
