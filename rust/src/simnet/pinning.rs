//! Unit → core placement policies, plus best-effort real OS pinning.
//!
//! The paper pins every processing unit to a physical core and constrains
//! memory to the local NUMA domain (§V-A). Our units are threads; the
//! placement policy decides which *modelled* core each unit occupies (which
//! determines the cost tier of every communication pair), and
//! [`pin_current_thread`] additionally pins the OS thread to a real core so
//! that measurements are not polluted by migration.

use super::{CoreCoord, Topology};

/// How units are laid out onto the modelled topology.
#[derive(Debug, Clone)]
pub enum PinPolicy {
    /// Fill cores in order: unit *i* → core *i* (NUMA domain fills up before
    /// the next one is used). This is the paper's intra-NUMA configuration
    /// for small unit counts.
    Block,
    /// Round-robin over NUMA domains: consecutive units land on different
    /// NUMA domains of the same node, then different nodes.
    ScatterNuma,
    /// One unit per node: consecutive units land on different nodes (the
    /// inter-node configuration).
    ScatterNode,
    /// Explicit coordinates, one per unit.
    Custom(Vec<CoreCoord>),
}

impl PinPolicy {
    /// Compute the coordinate of every unit under this policy.
    ///
    /// Placement wraps modulo the topology size, so oversubscription is
    /// allowed (two units may share a modelled core).
    pub fn place(&self, topo: &Topology, units: usize) -> Vec<CoreCoord> {
        match self {
            PinPolicy::Block => (0..units).map(|u| topo.coord_of(u % topo.total_cores())).collect(),
            PinPolicy::ScatterNuma => {
                let domains = topo.nodes * topo.numa_per_node;
                (0..units)
                    .map(|u| {
                        let domain = u % domains;
                        let core = (u / domains) % topo.cores_per_numa;
                        CoreCoord {
                            node: domain / topo.numa_per_node,
                            numa: domain % topo.numa_per_node,
                            core,
                        }
                    })
                    .collect()
            }
            PinPolicy::ScatterNode => (0..units)
                .map(|u| {
                    let node = u % topo.nodes;
                    let within = (u / topo.nodes) % topo.cores_per_node();
                    CoreCoord {
                        node,
                        numa: within / topo.cores_per_numa,
                        core: within % topo.cores_per_numa,
                    }
                })
                .collect(),
            PinPolicy::Custom(coords) => {
                assert!(
                    coords.len() >= units,
                    "Custom placement has {} coords for {units} units",
                    coords.len()
                );
                coords[..units].to_vec()
            }
        }
    }
}

/// Pin the calling OS thread to `cpu % available_cpus`. Best effort: returns
/// `false` (and leaves affinity unchanged) if the syscall fails or the
/// platform is not Linux.
pub fn pin_current_thread(cpu: usize) -> bool {
    #[cfg(target_os = "linux")]
    unsafe {
        let ncpu = libc::sysconf(libc::_SC_NPROCESSORS_ONLN);
        if ncpu <= 0 {
            return false;
        }
        let cpu = cpu % ncpu as usize;
        let mut set: libc::cpu_set_t = std::mem::zeroed();
        libc::CPU_SET(cpu, &mut set);
        libc::sched_setaffinity(0, std::mem::size_of::<libc::cpu_set_t>(), &set) == 0
    }
    #[cfg(not(target_os = "linux"))]
    {
        let _ = cpu;
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::Tier;

    #[test]
    fn block_fills_numa_first() {
        let t = Topology::hermit(2);
        let coords = PinPolicy::Block.place(&t, 10);
        // first 8 units share NUMA 0 of node 0
        for c in &coords[..8] {
            assert_eq!((c.node, c.numa), (0, 0));
        }
        assert_eq!((coords[8].node, coords[8].numa), (0, 1));
    }

    #[test]
    fn scatter_numa_pairs_are_inter_numa() {
        let t = Topology::hermit(2);
        let coords = PinPolicy::ScatterNuma.place(&t, 4);
        assert_eq!(t.tier(coords[0], coords[1]), Tier::InterNuma);
        assert_eq!(coords[0].node, coords[1].node);
    }

    #[test]
    fn scatter_node_pairs_are_inter_node() {
        let t = Topology::hermit(2);
        let coords = PinPolicy::ScatterNode.place(&t, 4);
        assert_eq!(t.tier(coords[0], coords[1]), Tier::InterNode);
        // unit 2 wraps back to node 0
        assert_eq!(coords[2].node, 0);
        assert_ne!(coords[0], coords[2]);
    }

    #[test]
    fn custom_placement_is_verbatim() {
        let t = Topology::hermit(1);
        let cs = vec![t.coord_of(3), t.coord_of(17)];
        let placed = PinPolicy::Custom(cs.clone()).place(&t, 2);
        assert_eq!(placed, cs);
    }

    #[test]
    fn oversubscription_wraps() {
        let t = Topology::flat(2);
        let coords = PinPolicy::Block.place(&t, 5);
        assert_eq!(coords[0], coords[2]);
        assert_eq!(coords[0], coords[4]);
    }

    #[test]
    fn real_pinning_is_best_effort() {
        // Must not panic regardless of environment.
        let _ = pin_current_thread(0);
    }
}
