//! # DART — a PGAS runtime system on an MPI-3 RMA substrate
//!
//! This crate is a from-scratch reproduction of **"DART-MPI: An MPI-based
//! Implementation of a PGAS Runtime System"** (Zhou et al., PGAS'14).
//!
//! It is organised as the paper's system plus every substrate it depends on:
//!
//! - [`simnet`] — cluster topology (nodes × NUMA domains × cores) and a
//!   calibrated network cost model standing in for the Cray XE6 "Hermit"
//!   testbed and its Gemini interconnect.
//! - [`mpisim`] — an MPI-3 subset implemented over OS threads and shared
//!   memory: communicators, groups, two-sided p2p, RMA windows with
//!   passive-target synchronization, request-based RMA, atomics,
//!   collectives — blocking and nonblocking ([`mpisim::icoll`]) — and an
//!   asynchronous progress engine ([`mpisim::progress`]). This is the
//!   communication substrate DART is built on, playing the role Cray
//!   MPICH played in the paper.
//! - [`dart`] — the paper's contribution: the DART PGAS runtime API
//!   (teams/groups, global memory with 128-bit global pointers, one-sided
//!   blocking/non-blocking put/get, collectives, and MCS queue locks) mapped
//!   onto MPI-3 RMA — with a unified communication engine
//!   ([`dart::engine`]) that caches segment resolution, moves strided
//!   patterns as single vector-typed requests, batches remote completion
//!   behind explicit flushes, and retires deferred work in the background
//!   through the progress engine ([`dart::ProgressMode`]).
//! - [`dash`] — typed distributed data structures on top of `dart` (the
//!   layer the DASH C++ PGAS library plays in the paper's stack):
//!   distribution [`dash::Pattern`]s (BLOCKED/CYCLIC/BLOCKCYCLIC/TILED),
//!   [`dash::Array`]/[`dash::Matrix`] containers with run-coalesced bulk
//!   transfers and owner-computes local views, and the
//!   [`dash::algorithms`] family including pattern redistribution.
//! - [`runtime`] — an executor for AOT-compiled JAX/Pallas compute
//!   artifacts so PGAS applications can run their local compute step
//!   without Python on the request path (native backend offline; the API
//!   is PJRT-shaped so the XLA client can be swapped back in).
//! - [`apps`] — PGAS mini-applications (distributed stencil, SUMMA matmul)
//!   used by the examples and the end-to-end tests.
//! - [`bench_util`] — the measurement harness that regenerates the paper's
//!   figures 8–15.
//! - [`testing`] — a minimal property-based testing framework used by the
//!   test suite.
//!
//! ## Quickstart
//!
//! ```no_run
//! use dart::dart::{run, DartConfig, DART_TEAM_ALL};
//!
//! // SPMD launch: 4 units, each runs the closure with its own env.
//! run(DartConfig::with_units(4), |env| {
//!     let myid = env.myid();
//!     let size = env.size();
//!     assert_eq!(size, 4);
//!     env.barrier(DART_TEAM_ALL).unwrap();
//! }).unwrap();
//! ```

#![warn(missing_docs)]

pub mod apps;
pub mod bench_util;
pub mod dart;
pub mod dash;
pub mod mpisim;
pub mod runtime;
pub mod simnet;
pub mod testing;
