//! Regenerates the paper's fig13 (see bench_util::figure). Run via
//! `cargo bench --bench fig13_bw_blocking_get`; set DART_BENCH_QUICK=1 for a short sweep.
use dart::bench_util::figure::{run_figure, Figure};

fn main() {
    run_figure(Figure::BwBlockingGet);
}
