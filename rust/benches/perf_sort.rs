//! §SORT — distributed sample sort: data-dependent element routing.
//!
//! Drives `apps::samplesort` through its bucketed redistribution on a
//! grid of collective modes, fast-path settings, and key distributions,
//! writing `BENCH_sort.json`:
//!
//! - **collectives** — `flat` vs `hier` two-level (splitter and count
//!   allgathers decompose intra-node first);
//! - **fastpath** — the shmem zero-copy fast path `on` vs `off` (the
//!   bucket scatter's same-node puts complete by direct store when on);
//! - **dist** — `uniform` keys vs `skewed` heavy-duplicate keys (bucket
//!   imbalance, some buckets empty).
//!
//! Deterministic correctness gates, asserted here so CI catches
//! regressions: every cell preserves the input multiset (permutation
//! check), reports global sortedness, agrees bit-for-bit on the
//! position-weighted output checksum across config cells, and matches
//! the sequential oracle's checksums.

use dart::apps::samplesort::{reference_checksums, run_distributed, KeyDist, SortConfig};
use dart::bench_util::{quick_mode, Samples};
use dart::dart::{run, DartConfig, DART_TEAM_ALL};
use dart::simnet::PinPolicy;
use std::sync::Mutex;
use std::time::Instant;

/// One measured configuration (uniform row schema for the JSON).
#[derive(Clone, Default)]
struct Shot {
    collectives: &'static str,
    fastpath: &'static str,
    dist: &'static str,
    units: u64,
    n: u64,
    /// Order-independent output multiset checksum (= input's iff the
    /// sort is a permutation).
    checksum: u64,
    /// Position-weighted output checksum (the cross-cell oracle).
    position_checksum: u64,
    /// Largest bucket — the skew measure.
    max_bucket: u64,
    /// Coalesced one-sided ops for both redistributions, team-wide.
    redist_ops: u64,
    /// Sorted keys per second over the median repetition.
    keys_per_sec: f64,
    wall_ms: f64,
}

fn cfg(units: usize, nodes: usize, hier: bool, fastpath: bool) -> DartConfig {
    DartConfig::hermit(units, nodes)
        .with_pin(PinPolicy::ScatterNode)
        .with_pools(1 << 20, 1 << 22)
        .with_shmem_windows(true)
        .with_locality_fastpath(fastpath)
        .with_hierarchical_collectives(hier)
}

fn dist_label(dist: KeyDist) -> &'static str {
    match dist {
        KeyDist::Uniform => "uniform",
        KeyDist::Skewed => "skewed",
        KeyDist::AllEqual => "all-equal",
        KeyDist::Sorted => "sorted",
        KeyDist::Reverse => "reverse",
    }
}

fn measure(
    units: usize,
    nodes: usize,
    n: usize,
    dist: KeyDist,
    hier: bool,
    fastpath: bool,
    reps: usize,
) -> Shot {
    let sort = SortConfig { n, seed: 0x50B7_5EED, dist, oversample: 16, team: DART_TEAM_ALL };
    let out = Mutex::new(Shot::default());
    run(cfg(units, nodes, hier, fastpath), |env| {
        let mut s = Samples::new();
        let mut shot = Shot::default();
        for rep in 0..reps {
            env.barrier(DART_TEAM_ALL).unwrap();
            let t = Instant::now();
            let report = run_distributed(env, &sort).unwrap();
            let wall = t.elapsed();
            s.push(wall.as_secs_f64() * 1e3);
            if env.myid() == 0 {
                assert!(report.sorted_ok, "{}: output not sorted", dist_label(dist));
                assert_eq!(
                    report.checksum_in, report.checksum_out,
                    "{}: output is not a permutation of the input",
                    dist_label(dist)
                );
                assert_eq!(report.count, n as u64);
                if rep > 0 {
                    assert_eq!(
                        shot.position_checksum, report.position_checksum,
                        "sort output changed between repetitions"
                    );
                }
                shot = Shot {
                    collectives: if hier { "hier" } else { "flat" },
                    fastpath: if fastpath { "on" } else { "off" },
                    dist: dist_label(dist),
                    units: units as u64,
                    n: n as u64,
                    checksum: report.checksum_out,
                    position_checksum: report.position_checksum,
                    max_bucket: report.max_bucket,
                    redist_ops: report.redist_ops,
                    keys_per_sec: 0.0,
                    wall_ms: 0.0,
                };
            }
        }
        if env.myid() == 0 {
            shot.wall_ms = s.median();
            shot.keys_per_sec = n as f64 / (s.median() / 1e3);
            *out.lock().unwrap() = shot;
        }
        env.barrier(DART_TEAM_ALL).unwrap();
    })
    .unwrap();
    out.into_inner().unwrap()
}

fn json_shot(s: &Shot) -> String {
    format!(
        "{{\"collectives\":\"{}\",\"fastpath\":\"{}\",\"dist\":\"{}\",\"units\":{},\"n\":{},\
         \"checksum\":{},\"position_checksum\":{},\"max_bucket\":{},\"redist_ops\":{},\
         \"keys_per_sec\":{:.1},\"wall_ms\":{:.3}}}",
        s.collectives,
        s.fastpath,
        s.dist,
        s.units,
        s.n,
        s.checksum,
        s.position_checksum,
        s.max_bucket,
        s.redist_ops,
        s.keys_per_sec,
        s.wall_ms
    )
}

fn main() {
    let quick = quick_mode();
    let reps = if quick { 2 } else { 3 };
    let (units, nodes) = if quick { (8, 2) } else { (32, 4) };
    let n = if quick { 1 << 12 } else { 1 << 16 };
    println!("==== §SORT — distributed sample sort through the bucketed redistribution ====");

    let mut shots = Vec::new();
    for dist in [KeyDist::Uniform, KeyDist::Skewed] {
        for hier in [false, true] {
            for fastpath in [true, false] {
                shots.push(measure(units, nodes, n, dist, hier, fastpath, reps));
            }
        }
    }

    println!(
        "\n{:>8} {:>6} {:>9} {:>6} {:>10} {:>11} {:>12} {:>10}",
        "dist", "coll", "fastpath", "units", "max_bkt", "redist_ops", "keys/s", "wall_ms"
    );
    for s in &shots {
        println!(
            "{:>8} {:>6} {:>9} {:>6} {:>10} {:>11} {:>12.0} {:>10.3}",
            s.dist, s.collectives, s.fastpath, s.units, s.max_bucket, s.redist_ops,
            s.keys_per_sec, s.wall_ms
        );
    }

    // --- correctness gates (deterministic — safe to assert in CI) -------
    // 1. The output order is config-independent: all four cells of each
    //    distribution agree bit-for-bit, and both match the oracle.
    for dist in [KeyDist::Uniform, KeyDist::Skewed] {
        let label = dist_label(dist);
        let sort = SortConfig { n, seed: 0x50B7_5EED, dist, oversample: 16, team: DART_TEAM_ALL };
        let (multiset, position) = reference_checksums(&sort);
        for s in shots.iter().filter(|s| s.dist == label) {
            assert_eq!(
                (s.checksum, s.position_checksum),
                (multiset, position),
                "{label} {}/{} disagrees with the sequential oracle",
                s.collectives,
                s.fastpath
            );
        }
    }
    // 2. The redistribution actually coalesces: ops stay far below one
    //    per element (each unit ships at most one run per bucket).
    for s in &shots {
        assert!(s.redist_ops > 0, "{}: no redistribution ops recorded", s.dist);
        assert!(
            s.redist_ops <= 2 * s.units * (s.units + 1),
            "{} {}/{}: {} redistribution ops for {} units — coalescing regressed",
            s.dist,
            s.collectives,
            s.fastpath,
            s.redist_ops,
            s.units
        );
    }

    let rows: Vec<String> = shots.iter().map(json_shot).collect();
    let json = format!(
        "{{\"bench\":\"perf_sort\",\"reps\":{reps},\"n\":{n},\"results\":[{}]}}",
        rows.join(",")
    );
    std::fs::write("BENCH_sort.json", format!("{json}\n")).expect("write BENCH_sort.json");
    println!("\nwrote BENCH_sort.json");
}
