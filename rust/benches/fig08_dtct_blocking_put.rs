//! Regenerates the paper's fig08 (see bench_util::figure). Run via
//! `cargo bench --bench fig08_dtct_blocking_put`; set DART_BENCH_QUICK=1 for a short sweep.
use dart::bench_util::figure::{run_figure, Figure};

fn main() {
    run_figure(Figure::DtctBlockingPut);
}
