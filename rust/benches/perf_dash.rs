//! §dash — pattern redistribution bandwidth and operation coalescing.
//!
//! The `dash` layer's claim: a bulk redistribution between two
//! distribution patterns issues **runs**, not elements — a
//! BLOCKED → BLOCKCYCLIC(b) copy of `n` elements costs `~n/b` one-sided
//! operations, a BLOCKED → BLOCKED copy `~1` per unit, while
//! BLOCKED → CYCLIC is the adversarial floor (run length 1, one op per
//! element). This bench measures `dash::algorithms::copy` from a BLOCKED
//! `Array<f64>` into each destination pattern, reading the issued-run and
//! byte counts from `Metrics::{dash_coalesced_runs, dash_redist_bytes}`
//! and the engine-retired share from `Metrics::overlap_bytes`.
//!
//! Results print as a table and land in `BENCH_dash.json`
//! (`scripts/check_bench_json.py` validates the schema in CI).

use dart::bench_util::{bandwidth_mb_s, fmt_ns, quick_mode, Samples};
use dart::dart::{run, DartConfig, DART_TEAM_ALL};
use dart::dash::{algorithms, Array, Pattern};
use dart::mpisim::MpiOp;
use std::sync::Mutex;
use std::time::Instant;

const UNITS: usize = 4;

/// One measured configuration.
#[derive(Clone, Default)]
struct Shot {
    pattern: &'static str,
    n: usize,
    /// One-sided ops issued per copy (team-wide).
    coalesced_runs: u64,
    /// Bytes moved per copy (team-wide) — `n × 8` by construction.
    redist_bytes: u64,
    /// Bytes the progress engine retired in the background.
    overlap_bytes: u64,
    /// Median wall-clock ns of one whole copy (including its barriers).
    copy_ns: f64,
}

impl Shot {
    fn bandwidth(&self) -> f64 {
        bandwidth_mb_s(self.redist_bytes as usize, self.copy_ns)
    }

    fn ops_per_element(&self) -> f64 {
        self.coalesced_runs as f64 / self.n as f64
    }
}

/// Destination pattern under test, keyed by a stable label.
fn dst_pattern(label: &str, n: usize, p: usize) -> Pattern {
    match label {
        "blocked" => Pattern::blocked(n, p).unwrap(),
        "cyclic" => Pattern::cyclic(n, p).unwrap(),
        "blockcyclic16" => Pattern::block_cyclic(n, p, 16).unwrap(),
        "blockcyclic256" => Pattern::block_cyclic(n, p, 256).unwrap(),
        // 64-row matrix view, 32×16 tiles over a 2×2 unit grid.
        "tiled" => Pattern::tiled(64, n / 64, 32, 16, 2, 2).unwrap(),
        other => panic!("unknown pattern label {other}"),
    }
}

fn measure(label: &'static str, n: usize, reps: usize) -> Shot {
    let out = Mutex::new(Shot::default());
    let cfg = DartConfig::hermit(UNITS, 2);
    run(cfg, |env| {
        let src: Array<'_, f64> =
            Array::new(env, DART_TEAM_ALL, Pattern::blocked(n, env.size()).unwrap()).unwrap();
        let dst: Array<'_, f64> =
            Array::new(env, DART_TEAM_ALL, dst_pattern(label, n, env.size())).unwrap();
        algorithms::transform(&src, |g, _| g as f64 * 1.5 + 0.25).unwrap();

        let runs0 = env.metrics.dash_coalesced_runs.get();
        let bytes0 = env.metrics.dash_redist_bytes.get();
        let overlap0 = env.metrics.overlap_bytes.get();
        let mut times = Samples::new();
        for _ in 0..reps {
            let t = Instant::now();
            algorithms::copy(&src, &dst).unwrap();
            times.push(t.elapsed().as_nanos() as f64);
        }
        // Spot-check the redistribution (full bit-exactness is asserted
        // by rust/tests/dash_tests.rs).
        for g in [0usize, 1, n / 2, n - 1] {
            let got = dst.get(g).unwrap();
            assert_eq!(got, g as f64 * 1.5 + 0.25, "redistribution corrupted element {g}");
        }
        let mine = [
            env.metrics.dash_coalesced_runs.get() - runs0,
            env.metrics.dash_redist_bytes.get() - bytes0,
            env.metrics.overlap_bytes.get() - overlap0,
        ];
        let mut team = [0u64; 3];
        env.allreduce(DART_TEAM_ALL, &mine, &mut team, MpiOp::Sum).unwrap();
        if env.myid() == 0 {
            *out.lock().unwrap() = Shot {
                pattern: label,
                n,
                coalesced_runs: team[0] / reps as u64,
                redist_bytes: team[1] / reps as u64,
                overlap_bytes: team[2] / reps as u64,
                copy_ns: times.median(),
            };
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        dst.free().unwrap();
        src.free().unwrap();
    })
    .unwrap();
    out.into_inner().unwrap()
}

fn json_shot(s: &Shot) -> String {
    format!(
        "{{\"pattern\":\"{}\",\"n\":{},\"coalesced_runs\":{},\"redist_bytes\":{},\
         \"overlap_bytes\":{},\"copy_ns\":{:.1},\"bandwidth_mb_s\":{:.1},\
         \"ops_per_element\":{:.4}}}",
        s.pattern,
        s.n,
        s.coalesced_runs,
        s.redist_bytes,
        s.overlap_bytes,
        s.copy_ns,
        s.bandwidth(),
        s.ops_per_element()
    )
}

fn main() {
    let (reps, sizes): (usize, Vec<usize>) =
        if quick_mode() { (3, vec![4096]) } else { (10, vec![16384, 65536]) };
    let patterns = ["blocked", "cyclic", "blockcyclic16", "blockcyclic256", "tiled"];
    println!("==== §dash — BLOCKED→X redistribution, {UNITS} units ====");
    let mut shots = Vec::new();
    for &n in &sizes {
        for label in patterns {
            shots.push(measure(label, n, reps));
        }
    }
    println!(
        "\n{:>16} {:>9} {:>10} {:>12} {:>12} {:>12}",
        "dst pattern", "elems", "runs", "ops/elem", "copy", "MB/s"
    );
    for s in &shots {
        println!(
            "{:>16} {:>9} {:>10} {:>12.4} {:>12} {:>12.0}",
            s.pattern,
            s.n,
            s.coalesced_runs,
            s.ops_per_element(),
            fmt_ns(s.copy_ns),
            s.bandwidth()
        );
    }
    println!(
        "\n(expected shape: cyclic ≈ 1 op/element — the un-coalescible floor; \
         blockcyclic ≈ 1/b; blocked ≈ p ops total)"
    );
    let rows: Vec<String> = shots.iter().map(json_shot).collect();
    let json = format!(
        "{{\"bench\":\"perf_dash\",\"units\":{UNITS},\"reps\":{reps},\"elem_bytes\":8,\
         \"results\":[{}]}}",
        rows.join(",")
    );
    std::fs::write("BENCH_dash.json", format!("{json}\n")).expect("write BENCH_dash.json");
    println!("\nwrote BENCH_dash.json");
}
