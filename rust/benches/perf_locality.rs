//! §Locality — flat vs hierarchical collectives, and the intra-node
//! zero-copy engine fast path.
//!
//! Three measured scenarios, written to `BENCH_locality.json`:
//!
//! - **allreduce** — the same 8 KiB `u64` reduction with
//!   `DartConfig::hierarchical_collectives` off (flat) and on (two-level),
//!   on the paper's two placements: single-node (all units share a node —
//!   the hierarchical path falls back to flat, so the two modes must tie)
//!   and multi-node (12 units round-robin over 3 nodes — every binomial
//!   hop of the flat tree crosses the interconnect, while the two-level
//!   path crosses it once per node). Results are asserted bit-identical
//!   between modes.
//! - **histogram** — the whole `apps::histogram` mini-app under the same
//!   mode × placement grid: the app-level win of switching its combining
//!   allreduce to the hierarchical path.
//! - **fastpath** — a batch of `put_async` + `flush_all` with
//!   shared-memory windows on, `DartConfig::locality_fastpath` on vs off,
//!   intra-node vs inter-node: on the fast path the puts complete on
//!   issue and the flush has nothing to drain
//!   (`Metrics::locality_fastpath_ops` counts them); inter-node traffic
//!   is unaffected by the knob.
//!
//! The multi-node allreduce pair additionally runs a **straggler series**
//! (`"faults":"straggler"` rows): one node drags every transfer it
//! touches by 4× via a single-class [`FaultPlan`]. The hierarchical tree
//! pays the straggler once per reduction, the flat tree on every hop
//! that touches it — so the hier advantage must survive.

use dart::apps::histogram::{self, HistogramConfig};
use dart::bench_util::{fmt_ns, quick_mode, Samples};
use dart::dart::{run, DartConfig, DART_TEAM_ALL};
use dart::mpisim::MpiOp;
use dart::simnet::{CoreCoord, FaultPlan, PinPolicy};
use std::sync::Mutex;
use std::time::Instant;

/// One measured configuration (uniform row schema for the JSON).
#[derive(Clone, Default)]
struct Shot {
    scenario: &'static str,
    placement: &'static str,
    mode: &'static str,
    /// Fault-plan label: `"none"` for the clean series, `"straggler"`
    /// for the one-slow-node ablation.
    faults: &'static str,
    /// Units in this scenario's launch (12 for the collective scenarios,
    /// 4 for the fastpath pair).
    units: u64,
    /// Timed repetitions behind this row's median (the histogram rows run
    /// fewer reps than the top-level count — the app is a whole run).
    reps: u64,
    /// Median wall-clock (= modelled time under the cost model) in ns.
    ns: f64,
    /// `Metrics::hier_coll_intra_ops` on unit 0 over the whole run.
    intra_ops: u64,
    /// `Metrics::hier_coll_inter_ops` on unit 0 over the whole run.
    inter_ops: u64,
    /// `Metrics::locality_fastpath_ops` on unit 0 over the whole run.
    fastpath_ops: u64,
    /// Scenario-defined correctness checksum (must match across modes).
    checksum: u64,
}

/// 12 units on a 3-node Hermit cluster; `multi` selects round-robin over
/// the nodes (every power-of-two rank distance crosses nodes) vs all
/// units block-placed on node 0.
fn coll_cfg(multi: bool, hier: bool) -> DartConfig {
    let pin = if multi { PinPolicy::ScatterNode } else { PinPolicy::Block };
    DartConfig::hermit(12, 3)
        .with_pin(pin)
        .with_pools(1 << 16, 1 << 20)
        .with_hierarchical_collectives(hier)
}

fn measure_allreduce(
    placement: &'static str,
    multi: bool,
    hier: bool,
    reps: usize,
    faults: Option<(&'static str, FaultPlan)>,
) -> Shot {
    const N: usize = 1024; // 8 KiB of u64 — the E1 regime
    let (fault_label, cfg) = match faults {
        Some((label, plan)) => (label, coll_cfg(multi, hier).with_fault_plan(plan)),
        None => ("none", coll_cfg(multi, hier)),
    };
    let out = Mutex::new(Shot::default());
    run(cfg, |env| {
        let mine = vec![env.myid() as u64 + 1; N];
        let mut red = vec![0u64; N];
        // Warm the split cache (sub-team creation) outside the timing.
        env.allreduce(DART_TEAM_ALL, &mine, &mut red, MpiOp::Sum).unwrap();
        let mut s = Samples::new();
        for _ in 0..reps {
            env.barrier(DART_TEAM_ALL).unwrap();
            let t = Instant::now();
            env.allreduce(DART_TEAM_ALL, &mine, &mut red, MpiOp::Sum).unwrap();
            s.push(t.elapsed().as_nanos() as f64);
        }
        if env.myid() == 0 {
            *out.lock().unwrap() = Shot {
                scenario: "allreduce",
                placement,
                mode: if hier { "hier" } else { "flat" },
                faults: fault_label,
                units: 12,
                reps: reps as u64,
                ns: s.median(),
                intra_ops: env.metrics.hier_coll_intra_ops.get(),
                inter_ops: env.metrics.hier_coll_inter_ops.get(),
                fastpath_ops: 0,
                checksum: red[0].wrapping_mul(0x9E37_79B9).wrapping_add(red[N - 1]),
            };
        }
        env.barrier(DART_TEAM_ALL).unwrap();
    })
    .unwrap();
    out.into_inner().unwrap()
}

fn measure_histogram(placement: &'static str, multi: bool, hier: bool, reps: usize) -> Shot {
    let out = Mutex::new(Shot::default());
    run(coll_cfg(multi, hier), |env| {
        let cfg = HistogramConfig::quick(512, 4000);
        let mut s = Samples::new();
        let mut checksum = 0u64;
        for _ in 0..reps {
            let t = Instant::now();
            let report = histogram::run_distributed(env, &cfg).unwrap();
            s.push(t.elapsed().as_nanos() as f64);
            checksum = report.checksum ^ report.total ^ report.modal_bin.1;
        }
        if env.myid() == 0 {
            *out.lock().unwrap() = Shot {
                scenario: "histogram",
                placement,
                mode: if hier { "hier" } else { "flat" },
                faults: "none",
                units: 12,
                reps: reps as u64,
                ns: s.median(),
                intra_ops: env.metrics.hier_coll_intra_ops.get(),
                inter_ops: env.metrics.hier_coll_inter_ops.get(),
                fastpath_ops: 0,
                checksum,
            };
        }
        env.barrier(DART_TEAM_ALL).unwrap();
    })
    .unwrap();
    out.into_inner().unwrap()
}

fn measure_fastpath(placement: &'static str, pin: PinPolicy, fastpath: bool, reps: usize) -> Shot {
    const PUTS: usize = 32;
    const BYTES: usize = 1024;
    let out = Mutex::new(Shot::default());
    let cfg = DartConfig::hermit(4, 2)
        .with_pin(pin)
        .with_pools(1 << 16, 1 << 20)
        .with_shmem_windows(true)
        .with_locality_fastpath(fastpath);
    run(cfg, |env| {
        let g = env.team_memalloc_aligned(DART_TEAM_ALL, (PUTS * BYTES) as u64).unwrap();
        let src = vec![0x5Au8; BYTES];
        env.barrier(DART_TEAM_ALL).unwrap();
        let mut s = Samples::new();
        for _ in 0..reps {
            if env.myid() == 0 {
                // Target is always unit 2; the placement decides whether
                // the pair shares a node (see the placements in main).
                let t = Instant::now();
                for i in 0..PUTS {
                    env.put_async(g.with_unit(2).add((i * BYTES) as u64), &src).unwrap();
                }
                env.flush_all(g).unwrap();
                s.push(t.elapsed().as_nanos() as f64);
            }
            env.barrier(DART_TEAM_ALL).unwrap();
        }
        // Correctness: the target observes the payload either way.
        if env.myid() == 2 {
            let mut got = vec![0u8; BYTES];
            env.local_read(g.with_unit(2), &mut got).unwrap();
            assert_eq!(got, src, "fast path delivered wrong bytes");
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        if env.myid() == 0 {
            let sum: u64 = src.iter().map(|&b| b as u64).sum();
            *out.lock().unwrap() = Shot {
                scenario: "fastpath",
                placement,
                mode: if fastpath { "on" } else { "off" },
                faults: "none",
                units: 4,
                reps: reps as u64,
                ns: s.median(),
                intra_ops: 0,
                inter_ops: 0,
                fastpath_ops: env.metrics.locality_fastpath_ops.get(),
                checksum: sum,
            };
        }
        env.team_memfree(DART_TEAM_ALL, g).unwrap();
    })
    .unwrap();
    out.into_inner().unwrap()
}

fn json_shot(s: &Shot) -> String {
    format!(
        "{{\"scenario\":\"{}\",\"placement\":\"{}\",\"mode\":\"{}\",\"faults\":\"{}\",\
         \"units\":{},\"reps\":{},\"ns\":{:.1},\"intra_ops\":{},\"inter_ops\":{},\
         \"fastpath_ops\":{},\"checksum\":{}}}",
        s.scenario, s.placement, s.mode, s.faults, s.units, s.reps, s.ns, s.intra_ops,
        s.inter_ops, s.fastpath_ops, s.checksum
    )
}

fn main() {
    let reps = if quick_mode() { 8 } else { 40 };
    println!("==== §Locality — hierarchical collectives + intra-node fast path ====");
    let mut shots = Vec::new();
    for (placement, multi) in [("single-node", false), ("multi-node", true)] {
        for hier in [false, true] {
            shots.push(measure_allreduce(placement, multi, hier, reps, None));
            shots.push(measure_histogram(placement, multi, hier, reps.min(12)));
        }
    }
    // Straggler series: node 0 of the 3-node cluster drags every transfer
    // it touches by 4× (all other fault classes quiet, fixed seed).
    let straggler =
        FaultPlan { straggler_nodes: 1, straggler_factor: 4.0, ..FaultPlan::quiet(0x57A6) };
    for hier in [false, true] {
        let series = Some(("straggler", straggler));
        shots.push(measure_allreduce("multi-node", true, hier, reps, series));
    }
    // The measured pair is unit 0 → unit 2. ScatterNode on 2 nodes puts
    // both on node 0 (intra-node); the Custom placement pins units 2,3 to
    // node 1 so the same pair crosses the interconnect.
    let inter_pin = PinPolicy::Custom(vec![
        CoreCoord { node: 0, numa: 0, core: 0 },
        CoreCoord { node: 0, numa: 0, core: 1 },
        CoreCoord { node: 1, numa: 0, core: 0 },
        CoreCoord { node: 1, numa: 0, core: 1 },
    ]);
    for (placement, pin) in [("intra-node", PinPolicy::ScatterNode), ("inter-node", inter_pin)] {
        shots.push(measure_fastpath(placement, pin.clone(), true, reps));
        shots.push(measure_fastpath(placement, pin, false, reps));
    }

    println!(
        "\n{:>10} {:>12} {:>6} {:>12} {:>10} {:>10} {:>10}",
        "scenario", "placement", "mode", "median", "intra", "inter", "fastpath"
    );
    for s in &shots {
        println!(
            "{:>10} {:>12} {:>6} {:>12} {:>10} {:>10} {:>10}",
            s.scenario,
            s.placement,
            s.mode,
            fmt_ns(s.ns),
            s.intra_ops,
            s.inter_ops,
            s.fastpath_ops
        );
    }

    // Correctness gates (deterministic — safe to assert in CI):
    // hierarchical results must be bit-identical to flat, per scenario and
    // placement.
    for scenario in ["allreduce", "histogram"] {
        for placement in ["single-node", "multi-node"] {
            let of = |mode: &str| {
                shots
                    .iter()
                    .find(|s| {
                        s.scenario == scenario
                            && s.placement == placement
                            && s.mode == mode
                            && s.faults == "none"
                    })
                    .map(|s| s.checksum)
                    .unwrap()
            };
            assert_eq!(
                of("flat"),
                of("hier"),
                "{scenario}/{placement}: hierarchical result differs from flat"
            );
        }
    }

    let clean = |mode: &str| {
        shots
            .iter()
            .find(|s| {
                s.scenario == "allreduce"
                    && s.placement == "multi-node"
                    && s.mode == mode
                    && s.faults == "none"
            })
            .unwrap()
    };
    let (flat, hier) = (clean("flat"), clean("hier"));
    println!(
        "\nmulti-node allreduce: flat {} vs hier {} → {:.2}× (expected > 1: one \
         interconnect crossing per node instead of one per tree edge)",
        fmt_ns(flat.ns),
        fmt_ns(hier.ns),
        flat.ns / hier.ns
    );

    // Straggler gates: a dragging node must not change the result, and
    // the two-level tree — which pays the straggler once per reduction
    // instead of on every hop — must keep its edge over the flat tree.
    let dragged = |mode: &str| {
        shots
            .iter()
            .find(|s| s.scenario == "allreduce" && s.faults == "straggler" && s.mode == mode)
            .unwrap()
    };
    let (s_flat, s_hier) = (dragged("flat"), dragged("hier"));
    assert_eq!(s_flat.checksum, flat.checksum, "straggler node corrupted the reduction");
    assert_eq!(s_flat.checksum, s_hier.checksum, "straggler: hier result differs from flat");
    assert!(
        s_hier.ns < s_flat.ns,
        "hier lost its edge under a straggler node: hier={} flat={}",
        fmt_ns(s_hier.ns),
        fmt_ns(s_flat.ns)
    );
    println!(
        "straggler allreduce:  flat {} vs hier {} → {:.2}× (one node dragging 4×)",
        fmt_ns(s_flat.ns),
        fmt_ns(s_hier.ns),
        s_flat.ns / s_hier.ns
    );

    let rows: Vec<String> = shots.iter().map(json_shot).collect();
    let json = format!(
        "{{\"bench\":\"perf_locality\",\"units\":12,\"reps\":{reps},\"results\":[{}]}}",
        rows.join(",")
    );
    std::fs::write("BENCH_locality.json", format!("{json}\n")).expect("write BENCH_locality.json");
    println!("\nwrote BENCH_locality.json");
}
