//! Regenerates the paper's fig11 (see bench_util::figure). Run via
//! `cargo bench --bench fig11_dtit_nonblocking_get`; set DART_BENCH_QUICK=1 for a short sweep.
use dart::bench_util::figure::{run_figure, Figure};

fn main() {
    run_figure(Figure::DtitNonblockingGet);
}
