//! Ablation A3 — DART collectives vs their raw MPI counterparts (§IV-B5:
//! "implement the DART collective interfaces straightforwardly by using
//! the MPI-3 collective counterparts ... we need to determine the
//! communicator based on the given teamID").
//!
//! The delta is exactly that communicator determination (teamlist lookup):
//! it should be nanoseconds on top of microsecond collectives.

use dart::bench_util::{fmt_ns, Samples};
use dart::dart::{run, DartConfig, DART_TEAM_ALL};
use dart::mpisim::{MpiOp, MpiType, World, WorldConfig};
use std::sync::Mutex;
use std::time::Instant;

const REPS: usize = 300;

fn bench_dart(units: usize) -> (f64, f64, f64) {
    let out = Mutex::new((0f64, 0f64, 0f64));
    run(DartConfig::hermit(units, 1), |env| {
        let mut barrier = Samples::new();
        let mut bcast = Samples::new();
        let mut allreduce = Samples::new();
        let mut buf = vec![0u8; 1024];
        for _ in 0..REPS {
            let t = Instant::now();
            env.barrier(DART_TEAM_ALL).unwrap();
            barrier.push(t.elapsed().as_nanos() as f64);
            let t = Instant::now();
            env.bcast(DART_TEAM_ALL, &mut buf, 0).unwrap();
            bcast.push(t.elapsed().as_nanos() as f64);
            let mine = [env.myid() as i64];
            let mut sum = [0i64];
            let t = Instant::now();
            env.allreduce(DART_TEAM_ALL, &mine, &mut sum, MpiOp::Sum).unwrap();
            allreduce.push(t.elapsed().as_nanos() as f64);
        }
        if env.myid() == 0 {
            *out.lock().unwrap() = (barrier.median(), bcast.median(), allreduce.median());
        }
    })
    .unwrap();
    out.into_inner().unwrap()
}

fn bench_mpi(units: usize) -> (f64, f64, f64) {
    let out = Mutex::new((0f64, 0f64, 0f64));
    World::run(WorldConfig::hermit(units, 1), |mpi| {
        let comm = mpi.comm_world();
        let mut barrier = Samples::new();
        let mut bcast = Samples::new();
        let mut allreduce = Samples::new();
        let mut buf = vec![0u8; 1024];
        for _ in 0..REPS {
            let t = Instant::now();
            comm.barrier().unwrap();
            barrier.push(t.elapsed().as_nanos() as f64);
            let t = Instant::now();
            comm.bcast(&mut buf, 0).unwrap();
            bcast.push(t.elapsed().as_nanos() as f64);
            let mine = (mpi.world_rank() as i64).to_ne_bytes();
            let mut sum = [0u8; 8];
            let t = Instant::now();
            comm.allreduce(&mine, &mut sum, MpiOp::Sum, MpiType::I64).unwrap();
            allreduce.push(t.elapsed().as_nanos() as f64);
        }
        if mpi.world_rank() == 0 {
            *out.lock().unwrap() = (barrier.median(), bcast.median(), allreduce.median());
        }
    });
    out.into_inner().unwrap()
}

fn main() {
    println!("==== Ablation A3 — DART collectives vs raw MPI collectives ====");
    println!("(medians over {REPS} reps, Hermit cost model; delta = teamID→communicator lookup)\n");
    println!(
        "{:>6} {:>12} {:>12} {:>12} {:>12} {:>14} {:>14}",
        "units", "barrier", "(raw)", "bcast 1K", "(raw)", "allreduce i64", "(raw)"
    );
    for units in [2usize, 4, 6, 8] {
        let (db, dc, da) = bench_dart(units);
        let (mb, mc, ma) = bench_mpi(units);
        println!(
            "{:>6} {:>12} {:>12} {:>12} {:>12} {:>14} {:>14}",
            units,
            fmt_ns(db),
            fmt_ns(mb),
            fmt_ns(dc),
            fmt_ns(mc),
            fmt_ns(da),
            fmt_ns(ma)
        );
    }
    println!("\nDART ≈ raw MPI on every collective — the paper's \"straightforward\" mapping.");
}
