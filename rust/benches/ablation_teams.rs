//! Ablation A2 — the paper's linearly-scanned `teamlist` (§IV-B2) versus a
//! direct-index map (`DartConfig::indexed_teamlist`, the "linked list /
//! index" alternative the paper's future work sketches).
//!
//! Every global-pointer dereference of a collective pointer performs a
//! teamlist lookup, so with many live teams the scan sits on the one-sided
//! hot path. The bench creates N teams, then measures `dart_put_blocking`
//! latency through the *last* team created (worst case for the scan), with
//! the cost model disabled so only software overhead is visible.

use dart::bench_util::{fmt_ns, Samples};
use dart::dart::{DartConfig, DartGroup, DART_TEAM_ALL};
use dart::simnet::CostModel;
use std::sync::Mutex;
use std::time::Instant;

const REPS: usize = 5000;

fn bench(teams: usize, indexed: bool) -> f64 {
    let mut cfg = DartConfig::with_units(2)
        .with_cost(CostModel::zero())
        .with_pools(1 << 16, 1 << 16);
    cfg.teamlist_size = teams + 2;
    cfg.indexed_teamlist = indexed;
    let out = Mutex::new(0f64);
    dart::dart::run(cfg, |env| {
        let grp = env.group_all();
        let mut last = DART_TEAM_ALL;
        for _ in 0..teams {
            last = env.team_create(DART_TEAM_ALL, &grp).unwrap().unwrap();
        }
        let g = env.team_memalloc_aligned(last, 64).unwrap();
        let dst = g.with_unit(1);
        env.barrier(DART_TEAM_ALL).unwrap();
        if env.myid() == 0 {
            let buf = [7u8; 8];
            let mut s = Samples::new();
            for _ in 0..REPS {
                let t = Instant::now();
                env.put_blocking(dst, &buf).unwrap();
                s.push(t.elapsed().as_nanos() as f64);
            }
            *out.lock().unwrap() = s.median();
        }
        env.barrier(DART_TEAM_ALL).unwrap();
    })
    .unwrap();
    out.into_inner().unwrap()
}

fn main() {
    println!("==== Ablation A2 — teamlist linear scan vs direct index ====");
    println!("(put_blocking through the LAST-created team; zero-cost network; {REPS} reps)\n");
    println!("{:>12} {:>16} {:>16} {:>9}", "live teams", "scan (ns/op)", "indexed (ns/op)", "ratio");
    for teams in [1usize, 8, 32, 128, 512] {
        let scan = bench(teams, false);
        let idx = bench(teams, true);
        println!("{:>12} {:>16} {:>16} {:>8.2}x", teams, fmt_ns(scan), fmt_ns(idx), scan / idx);
    }
    println!("\n\"the overhead brought by the scanning can be significant when the");
    println!("teamlist is extremely large\" (§VI) — the scan column grows with team");
    println!("count while the indexed column stays flat.");
}
