//! §GRAPH — Graph500-style BFS: data-dependent one-sided traffic.
//!
//! Drives `apps::bfs` over a seeded R-MAT `dash::Graph` on a 2×2 grid of
//! claim strategies and fast-path settings, writing `BENCH_graph.json`:
//!
//! - **mode** — `flat` CASes every candidate claim straight at the
//!   distributed parent array vs `hier`, which also turns on
//!   hierarchical collectives and combines candidates intra-node first
//!   (one claim per node-target pair crosses the interconnect);
//! - **fastpath** — the shmem CPU-atomic fast path `on` vs `off` (shmem
//!   windows stay on in both cells, only the fast path toggles).
//!
//! Deterministic correctness gates, asserted here so CI catches
//! regressions: all four cells produce the bit-identical level summary,
//! that summary equals the sequential oracle's, fast-path cells actually
//! complete atomics on the CPU path, and intra-node combining never
//! issues more claims than the flat protocol.

use dart::apps::bfs::{reference_summary, run_distributed, BfsConfig};
use dart::bench_util::{quick_mode, Samples};
use dart::dart::{run, DartConfig, DART_TEAM_ALL};
use dart::dash::GraphConfig;
use dart::simnet::PinPolicy;
use std::sync::Mutex;
use std::time::Instant;

/// One measured configuration (uniform row schema for the JSON).
#[derive(Clone, Default)]
struct Shot {
    mode: &'static str,
    fastpath: &'static str,
    units: u64,
    nverts: u64,
    /// Directed edges stored across the team after dedup.
    nedges: u64,
    reached: u64,
    max_level: i64,
    /// The deterministic level checksum (the cross-cell oracle).
    checksum: u64,
    rounds: u64,
    /// CAS claims issued team-wide (lower under intra-node combining).
    claims: u64,
    /// Atomics completed on the CPU-atomic fast path.
    fastpath_atomics: u64,
    /// Stored-edge traversal rate over the median repetition.
    teps: f64,
    wall_ms: f64,
}

fn cfg(units: usize, nodes: usize, hier: bool, fastpath: bool) -> DartConfig {
    DartConfig::hermit(units, nodes)
        .with_pin(PinPolicy::ScatterNode)
        .with_pools(1 << 20, 1 << 22)
        .with_shmem_windows(true)
        .with_locality_fastpath(fastpath)
        .with_hierarchical_collectives(hier)
}

fn measure(
    units: usize,
    nodes: usize,
    graph: GraphConfig,
    hier: bool,
    fastpath: bool,
    reps: usize,
) -> Shot {
    let bfs = BfsConfig { graph, root: 0, combine: hier, team: DART_TEAM_ALL };
    let out = Mutex::new(Shot::default());
    run(cfg(units, nodes, hier, fastpath), |env| {
        let mut s = Samples::new();
        let mut shot = Shot::default();
        for rep in 0..reps {
            env.barrier(DART_TEAM_ALL).unwrap();
            let t = Instant::now();
            let report = run_distributed(env, &bfs).unwrap();
            let wall = t.elapsed();
            s.push(wall.as_secs_f64() * 1e3);
            if env.myid() == 0 {
                if rep > 0 {
                    assert_eq!(
                        shot.checksum, report.summary.checksum,
                        "bfs checksum changed between repetitions"
                    );
                }
                shot = Shot {
                    mode: if hier { "hier" } else { "flat" },
                    fastpath: if fastpath { "on" } else { "off" },
                    units: units as u64,
                    nverts: graph.nverts() as u64,
                    nedges: report.nedges_stored,
                    reached: report.summary.reached,
                    max_level: report.summary.max_level,
                    checksum: report.summary.checksum,
                    rounds: report.rounds,
                    claims: report.claim_attempts,
                    fastpath_atomics: env.metrics.atomic_fastpath_ops.get(),
                    teps: 0.0,
                    wall_ms: 0.0,
                };
            }
        }
        if env.myid() == 0 {
            shot.wall_ms = s.median();
            shot.teps = shot.nedges as f64 / (s.median() / 1e3);
            *out.lock().unwrap() = shot;
        }
        env.barrier(DART_TEAM_ALL).unwrap();
    })
    .unwrap();
    out.into_inner().unwrap()
}

fn json_shot(s: &Shot) -> String {
    format!(
        "{{\"mode\":\"{}\",\"fastpath\":\"{}\",\"units\":{},\"nverts\":{},\"nedges\":{},\
         \"reached\":{},\"max_level\":{},\"checksum\":{},\"rounds\":{},\"claims\":{},\
         \"fastpath_atomics\":{},\"teps\":{:.1},\"wall_ms\":{:.3}}}",
        s.mode,
        s.fastpath,
        s.units,
        s.nverts,
        s.nedges,
        s.reached,
        s.max_level,
        s.checksum,
        s.rounds,
        s.claims,
        s.fastpath_atomics,
        s.teps,
        s.wall_ms
    )
}

fn main() {
    let quick = quick_mode();
    let reps = if quick { 2 } else { 3 };
    let (units, nodes) = if quick { (8, 2) } else { (32, 4) };
    let graph = GraphConfig {
        scale: if quick { 8 } else { 10 },
        edge_factor: if quick { 8 } else { 16 },
        seed: 0x6EA4_500D,
    };
    println!("==== §GRAPH — Graph500-style BFS over the distributed CSR ====");

    let mut shots = Vec::new();
    for hier in [false, true] {
        for fastpath in [true, false] {
            shots.push(measure(units, nodes, graph, hier, fastpath, reps));
        }
    }

    println!(
        "\n{:>6} {:>9} {:>6} {:>8} {:>8} {:>10} {:>10} {:>12} {:>10}",
        "mode", "fastpath", "units", "reached", "rounds", "claims", "fp_atomic", "teps", "wall_ms"
    );
    for s in &shots {
        println!(
            "{:>6} {:>9} {:>6} {:>8} {:>8} {:>10} {:>10} {:>12.0} {:>10.3}",
            s.mode, s.fastpath, s.units, s.reached, s.rounds, s.claims, s.fastpath_atomics,
            s.teps, s.wall_ms
        );
    }

    // --- correctness gates (deterministic — safe to assert in CI) -------
    // 1. Levels are race-independent: every cell agrees bit-for-bit.
    for s in &shots[1..] {
        assert_eq!(
            (shots[0].checksum, shots[0].reached, shots[0].max_level),
            (s.checksum, s.reached, s.max_level),
            "{}/{} disagrees with {}/{} on the level summary",
            s.mode,
            s.fastpath,
            shots[0].mode,
            shots[0].fastpath
        );
    }
    // 2. The distributed traversal equals the sequential oracle.
    let bfs = BfsConfig { graph, root: 0, combine: false, team: DART_TEAM_ALL };
    let oracle = reference_summary(&bfs);
    assert_eq!(
        (shots[0].reached, shots[0].max_level, shots[0].checksum),
        (oracle.reached, oracle.max_level, oracle.checksum),
        "distributed BFS disagrees with the sequential oracle"
    );
    // 3. Fast-path cells actually complete atomics on the CPU path.
    for s in shots.iter().filter(|s| s.fastpath == "on") {
        assert!(s.fastpath_atomics > 0, "{} cell issued no fast-path atomics", s.mode);
    }
    // 4. Intra-node combining never issues more claims than flat.
    for hier in shots.iter().filter(|s| s.mode == "hier") {
        let flat = shots
            .iter()
            .find(|s| s.mode == "flat" && s.fastpath == hier.fastpath)
            .unwrap();
        assert!(
            hier.claims <= flat.claims,
            "hier/{} issued {} claims, more than flat's {}",
            hier.fastpath,
            hier.claims,
            flat.claims
        );
    }

    let rows: Vec<String> = shots.iter().map(json_shot).collect();
    let json = format!(
        "{{\"bench\":\"perf_graph\",\"reps\":{reps},\"scale\":{},\"edge_factor\":{},\
         \"results\":[{}]}}",
        graph.scale,
        graph.edge_factor,
        rows.join(",")
    );
    std::fs::write("BENCH_graph.json", format!("{json}\n")).expect("write BENCH_graph.json");
    println!("\nwrote BENCH_graph.json");
}
