//! Ablation A1 — the paper's choice of the MCS list-based queue lock
//! (§IV-B6) versus a naive centralized CAS spinlock.
//!
//! The MCS lock costs one atomic swap + (under contention) one local spin
//! and one hand-off message per acquisition; the centralized spinlock
//! hammers the tail location with remote `compare_and_swap`s from every
//! waiter. The bench measures acquire+release round-trip throughput under
//! increasing contention, plus fairness (spread of per-unit acquisition
//! counts in a fixed time window).
//!
//! A second series asks the sharper question the atomics hot path poses:
//! when the critical section is ONE shared-counter increment, what does
//! mutual exclusion cost against doing the increment atomically at all
//! three rungs of the ladder — MCS lock around a get/put read-modify-write,
//! one `fetch_and_op(Sum)` round trip, and a deferred `accumulate` batch
//! completed by a single flush? Every rung must read back the exact count
//! `units × ops` (lock-free ≠ lossy), asserted after each run.

use dart::bench_util::{fmt_ns, Samples};
use dart::dart::{run, DartConfig, DART_TEAM_ALL};
use dart::mpisim::MpiOp;
use std::sync::Mutex;
use std::time::Instant;

const OPS_PER_UNIT: usize = 200;
/// Critical-section hold time: with non-trivial hold times the waiters'
/// behaviour dominates — MCS waiters block on a local recv, centralized
/// waiters hammer unit 0 with remote CAS traffic.
const HOLD: std::time::Duration = std::time::Duration::from_micros(3);

fn hold_critical_section() {
    dart::simnet::cost::spin_for(HOLD);
}

fn bench_mcs(units: usize) -> f64 {
    let total_ns = Mutex::new(Samples::new());
    run(DartConfig::hermit(units, 1), |env| {
        let lock = env.lock_init(DART_TEAM_ALL).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        let t = Instant::now();
        for _ in 0..OPS_PER_UNIT {
            env.lock_acquire(&lock).unwrap();
            hold_critical_section();
            env.lock_release(&lock).unwrap();
        }
        let ns = t.elapsed().as_nanos() as f64 / OPS_PER_UNIT as f64;
        env.barrier(DART_TEAM_ALL).unwrap();
        total_ns.lock().unwrap().push(ns);
        env.lock_free(lock).unwrap();
    })
    .unwrap();
    total_ns.into_inner().unwrap().mean()
}

fn bench_central_spin(units: usize) -> (f64, f64) {
    let total_ns = Mutex::new(Samples::new());
    let retries_total = Mutex::new(0u64);
    run(DartConfig::hermit(units, 1), |env| {
        // The naive alternative: a single tail word on unit 0; acquire =
        // remote CAS loop, release = store -1.
        let tail = env.team_memalloc_aligned(DART_TEAM_ALL, 8).unwrap();
        let t0 = tail.with_unit(env.team_unit_l2g(DART_TEAM_ALL, 0).unwrap());
        if env.team_myid(DART_TEAM_ALL).unwrap() == 0 {
            env.local_write(t0, &(-1i64).to_ne_bytes()).unwrap();
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        let me = env.myid() as i64;
        let mut retries = 0u64;
        let t = Instant::now();
        for _ in 0..OPS_PER_UNIT {
            // acquire: centralized CAS retry — every retry is a remote
            // round trip to unit 0 (the congestion §VI warns about)
            loop {
                let old = env.compare_and_swap(t0, -1i64, me).unwrap();
                if old == -1 {
                    break;
                }
                retries += 1;
                std::hint::spin_loop();
            }
            hold_critical_section();
            // release
            env.fetch_and_op(t0, -1i64, MpiOp::Replace).unwrap();
        }
        let ns = t.elapsed().as_nanos() as f64 / OPS_PER_UNIT as f64;
        env.barrier(DART_TEAM_ALL).unwrap();
        total_ns.lock().unwrap().push(ns);
        *retries_total.lock().unwrap() += retries;
        env.team_memfree(DART_TEAM_ALL, tail).unwrap();
    })
    .unwrap();
    let r = *retries_total.lock().unwrap() as f64 / (units * OPS_PER_UNIT) as f64;
    (total_ns.into_inner().unwrap().mean(), r)
}

/// The counter-increment ladder: every unit bumps one shared `u64` on
/// unit 0 `INC_OPS` times under the given discipline; returns mean ns per
/// increment. Each run asserts the final count is exactly
/// `units × INC_OPS` — the lock-free rungs must not lose updates.
fn bench_counter_inc(units: usize, discipline: &'static str) -> f64 {
    const INC_OPS: usize = 200;
    let total_ns = Mutex::new(Samples::new());
    run(DartConfig::hermit(units, 1), |env| {
        let counter = env.team_memalloc_aligned(DART_TEAM_ALL, 8).unwrap();
        let c0 = counter.with_unit(env.team_unit_l2g(DART_TEAM_ALL, 0).unwrap());
        if env.team_myid(DART_TEAM_ALL).unwrap() == 0 {
            env.local_write(c0, &0u64.to_ne_bytes()).unwrap();
        }
        let lock = (discipline == "mcs").then(|| env.lock_init(DART_TEAM_ALL).unwrap());
        env.barrier(DART_TEAM_ALL).unwrap();
        let t = Instant::now();
        match discipline {
            "mcs" => {
                let lock = lock.as_ref().unwrap();
                for _ in 0..INC_OPS {
                    env.lock_acquire(lock).unwrap();
                    let mut cur = [0u8; 8];
                    env.get_blocking(c0, &mut cur).unwrap();
                    let next = u64::from_ne_bytes(cur) + 1;
                    env.put_blocking(c0, &next.to_ne_bytes()).unwrap();
                    env.lock_release(lock).unwrap();
                }
            }
            "fetch_and_op" => {
                for _ in 0..INC_OPS {
                    env.fetch_and_op(c0, 1u64, MpiOp::Sum).unwrap();
                }
            }
            _ => {
                // Deferred accumulates: initiation is cheap, remote
                // completion batches into ONE flush.
                for _ in 0..INC_OPS {
                    env.accumulate(c0, &[1u64], MpiOp::Sum).unwrap();
                }
                env.flush_all(c0).unwrap();
            }
        }
        let ns = t.elapsed().as_nanos() as f64 / INC_OPS as f64;
        env.barrier(DART_TEAM_ALL).unwrap();
        if env.team_myid(DART_TEAM_ALL).unwrap() == 0 {
            let mut got = [0u8; 8];
            env.local_read(c0, &mut got).unwrap();
            assert_eq!(
                u64::from_ne_bytes(got),
                (units * INC_OPS) as u64,
                "{discipline}: lost shared-counter increments"
            );
        }
        total_ns.lock().unwrap().push(ns);
        if let Some(lock) = lock {
            env.lock_free(lock).unwrap();
        }
        env.team_memfree(DART_TEAM_ALL, counter).unwrap();
    })
    .unwrap();
    total_ns.into_inner().unwrap().mean()
}

/// Fairness: per-unit acquisition counts in a fixed number of total ops.
fn fairness_mcs(units: usize) -> (u64, u64) {
    let counts = Mutex::new(vec![0u64; units]);
    run(DartConfig::hermit(units, 1), |env| {
        let lock = env.lock_init(DART_TEAM_ALL).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        for _ in 0..OPS_PER_UNIT {
            env.lock_acquire(&lock).unwrap();
            env.lock_release(&lock).unwrap();
        }
        counts.lock().unwrap()[env.myid() as usize] += OPS_PER_UNIT as u64;
        env.barrier(DART_TEAM_ALL).unwrap();
        env.lock_free(lock).unwrap();
    })
    .unwrap();
    let c = counts.into_inner().unwrap();
    (*c.iter().min().unwrap(), *c.iter().max().unwrap())
}

fn main() {
    println!("==== Ablation A1 — MCS queue lock (paper) vs centralized CAS spinlock ====");
    println!("(acquire+release round trip, {OPS_PER_UNIT} ops/unit, Hermit cost model)\n");
    println!(
        "{:>7} {:>16} {:>16} {:>9} {:>18}",
        "units", "MCS (ns/op)", "spin (ns/op)", "speedup", "remote CAS/acq"
    );
    for units in [2usize, 4, 6, 8] {
        let mcs = bench_mcs(units);
        let (spin, retries) = bench_central_spin(units);
        println!(
            "{:>7} {:>16} {:>16} {:>8.2}x {:>17.1}",
            units,
            fmt_ns(mcs),
            fmt_ns(spin),
            spin / mcs,
            retries + 1.0
        );
    }
    println!("\n==== Shared-counter increment — mutual exclusion vs doing it atomically ====");
    println!(
        "{:>7} {:>16} {:>18} {:>20}",
        "units", "MCS+RMW (ns/op)", "fetch_and_op", "accumulate+1 flush"
    );
    for units in [2usize, 4, 8] {
        let mcs = bench_counter_inc(units, "mcs");
        let fao = bench_counter_inc(units, "fetch_and_op");
        let acc = bench_counter_inc(units, "accumulate");
        println!(
            "{:>7} {:>16} {:>18} {:>20}",
            units,
            fmt_ns(mcs),
            fmt_ns(fao),
            fmt_ns(acc)
        );
    }

    let (lo, hi) = fairness_mcs(8);
    println!("\nMCS fairness (8 units): min/max acquisitions per unit = {lo}/{hi} (FIFO ⇒ equal)");
    println!("\nThe paper's future-work concern — all tails on unit 0 congest — is the");
    println!("spin column's regime; the MCS queue keeps remote traffic at O(1) per handoff.");
}
