//! Ablation A1 — the paper's choice of the MCS list-based queue lock
//! (§IV-B6) versus a naive centralized CAS spinlock.
//!
//! The MCS lock costs one atomic swap + (under contention) one local spin
//! and one hand-off message per acquisition; the centralized spinlock
//! hammers the tail location with remote `compare_and_swap`s from every
//! waiter. The bench measures acquire+release round-trip throughput under
//! increasing contention, plus fairness (spread of per-unit acquisition
//! counts in a fixed time window).

use dart::bench_util::{fmt_ns, Samples};
use dart::dart::{run, DartConfig, DART_TEAM_ALL};
use dart::mpisim::MpiOp;
use std::sync::Mutex;
use std::time::Instant;

const OPS_PER_UNIT: usize = 200;
/// Critical-section hold time: with non-trivial hold times the waiters'
/// behaviour dominates — MCS waiters block on a local recv, centralized
/// waiters hammer unit 0 with remote CAS traffic.
const HOLD: std::time::Duration = std::time::Duration::from_micros(3);

fn hold_critical_section() {
    dart::simnet::cost::spin_for(HOLD);
}

fn bench_mcs(units: usize) -> f64 {
    let total_ns = Mutex::new(Samples::new());
    run(DartConfig::hermit(units, 1), |env| {
        let lock = env.lock_init(DART_TEAM_ALL).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        let t = Instant::now();
        for _ in 0..OPS_PER_UNIT {
            env.lock_acquire(&lock).unwrap();
            hold_critical_section();
            env.lock_release(&lock).unwrap();
        }
        let ns = t.elapsed().as_nanos() as f64 / OPS_PER_UNIT as f64;
        env.barrier(DART_TEAM_ALL).unwrap();
        total_ns.lock().unwrap().push(ns);
        env.lock_free(lock).unwrap();
    })
    .unwrap();
    total_ns.into_inner().unwrap().mean()
}

fn bench_central_spin(units: usize) -> (f64, f64) {
    let total_ns = Mutex::new(Samples::new());
    let retries_total = Mutex::new(0u64);
    run(DartConfig::hermit(units, 1), |env| {
        // The naive alternative: a single tail word on unit 0; acquire =
        // remote CAS loop, release = store -1.
        let tail = env.team_memalloc_aligned(DART_TEAM_ALL, 8).unwrap();
        let t0 = tail.with_unit(env.team_unit_l2g(DART_TEAM_ALL, 0).unwrap());
        if env.team_myid(DART_TEAM_ALL).unwrap() == 0 {
            env.local_write(t0, &(-1i64).to_ne_bytes()).unwrap();
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        let me = env.myid() as i64;
        let mut retries = 0u64;
        let t = Instant::now();
        for _ in 0..OPS_PER_UNIT {
            // acquire: centralized CAS retry — every retry is a remote
            // round trip to unit 0 (the congestion §VI warns about)
            loop {
                let old = env.compare_and_swap(t0, -1i64, me).unwrap();
                if old == -1 {
                    break;
                }
                retries += 1;
                std::hint::spin_loop();
            }
            hold_critical_section();
            // release
            env.fetch_and_op(t0, -1i64, MpiOp::Replace).unwrap();
        }
        let ns = t.elapsed().as_nanos() as f64 / OPS_PER_UNIT as f64;
        env.barrier(DART_TEAM_ALL).unwrap();
        total_ns.lock().unwrap().push(ns);
        *retries_total.lock().unwrap() += retries;
        env.team_memfree(DART_TEAM_ALL, tail).unwrap();
    })
    .unwrap();
    let r = *retries_total.lock().unwrap() as f64 / (units * OPS_PER_UNIT) as f64;
    (total_ns.into_inner().unwrap().mean(), r)
}

/// Fairness: per-unit acquisition counts in a fixed number of total ops.
fn fairness_mcs(units: usize) -> (u64, u64) {
    let counts = Mutex::new(vec![0u64; units]);
    run(DartConfig::hermit(units, 1), |env| {
        let lock = env.lock_init(DART_TEAM_ALL).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        for _ in 0..OPS_PER_UNIT {
            env.lock_acquire(&lock).unwrap();
            env.lock_release(&lock).unwrap();
        }
        counts.lock().unwrap()[env.myid() as usize] += OPS_PER_UNIT as u64;
        env.barrier(DART_TEAM_ALL).unwrap();
        env.lock_free(lock).unwrap();
    })
    .unwrap();
    let c = counts.into_inner().unwrap();
    (*c.iter().min().unwrap(), *c.iter().max().unwrap())
}

fn main() {
    println!("==== Ablation A1 — MCS queue lock (paper) vs centralized CAS spinlock ====");
    println!("(acquire+release round trip, {OPS_PER_UNIT} ops/unit, Hermit cost model)\n");
    println!(
        "{:>7} {:>16} {:>16} {:>9} {:>18}",
        "units", "MCS (ns/op)", "spin (ns/op)", "speedup", "remote CAS/acq"
    );
    for units in [2usize, 4, 6, 8] {
        let mcs = bench_mcs(units);
        let (spin, retries) = bench_central_spin(units);
        println!(
            "{:>7} {:>16} {:>16} {:>8.2}x {:>17.1}",
            units,
            fmt_ns(mcs),
            fmt_ns(spin),
            spin / mcs,
            retries + 1.0
        );
    }
    let (lo, hi) = fairness_mcs(8);
    println!("\nMCS fairness (8 units): min/max acquisitions per unit = {lo}/{hi} (FIFO ⇒ equal)");
    println!("\nThe paper's future-work concern — all tails on unit 0 congest — is the");
    println!("spin column's regime; the MCS queue keeps remote traffic at O(1) per handoff.");
}
