//! §Scale — thousand-unit weak scaling of the runtime itself.
//!
//! Weak-scaling sweep over world sizes 16 → 1024 (16 units per node),
//! each size running the same per-unit workload — barrier + allreduce +
//! one-sided ring put + flush — under three placements:
//!
//! - **flat** — single-level collectives, locality knobs off.
//! - **hier** — two-level (node-local + leader) collectives.
//! - **fastpath** — hier plus shared-memory windows and the intra-node
//!   zero-copy put fast path (the ring strides by the node count, so a
//!   unit's ring neighbour shares its node and the puts are eligible).
//!
//! Units are scatter-placed (round-robin over nodes), so the flat
//! binomial/dissemination trees cross the interconnect on every
//! small-distance hop while the hierarchical path crosses it only
//! between node leaders.
//!
//! All rows run under the pooled execution mode
//! ([`ExecMode::Pooled`]): every unit still gets an OS thread, but at
//! most `available_parallelism` of them are runnable at once — which is
//! what lets a 1024-unit world finish in wall-clock seconds. One extra
//! thread-per-rank run cross-checks that pooling does not change
//! results.
//!
//! Deterministic gates (asserted — safe in CI):
//!
//! - collective results are bit-identical across the three placements
//!   and across both execution modes;
//! - the lazily-populated channel table stays far below `units²`;
//! - the hierarchical placements cross nodes far less than flat, and
//!   the crossings saved grow with the node count;
//! - the fastpath rows retire ring puts on issue
//!   (`Metrics::locality_fastpath_ops > 0`), the flat rows never do.
//!
//! Results go to `BENCH_scale.json`. `DART_SCALE_MAX_UNITS` caps the
//! sweep (CI sets 256); `DART_BENCH_QUICK=1` trims repetitions.

use dart::bench_util::{fmt_ns, quick_mode, Samples};
use dart::dart::{run, DartConfig, UnitId, DART_TEAM_ALL};
use dart::mpisim::{ExecMode, MpiOp};
use dart::simnet::PinPolicy;
use std::sync::Mutex;
use std::time::Instant;

/// The weak-scaling sweep: 16 units per node, 1 → 64 nodes.
const SIZES: [usize; 4] = [16, 64, 256, 1024];
/// `u64` elements per unit in the allreduce (1 KiB — the E0 regime).
const RED: usize = 128;
/// Ring-put payload per unit per repetition.
const PUT_BYTES: usize = 1024;
/// DART calls per unit per repetition (2 barriers + allreduce + put +
/// flush) — the numerator of the aggregate ops/sec figure.
const OPS_PER_REP: f64 = 5.0;

/// One measured row of the sweep.
#[derive(Clone, Default)]
struct Shot {
    units: u64,
    nodes: u64,
    placement: &'static str,
    exec: &'static str,
    reps: u64,
    /// Aggregate DART calls per wall second across all units.
    ops_per_sec: f64,
    /// Median modelled time of one repetition (= wall time of the timed
    /// region under the cost model), unit 0.
    modelled_ns: f64,
    /// Whole-launch wall clock (spawn + warmup + timed + teardown).
    wall_ms: f64,
    /// Inter-node transfers booked across the timed region (unit 0's
    /// snapshot delta — deterministic up to barrier-tail skew).
    node_crossings: u64,
    /// Directed rank pairs populated in the channel table at the end.
    active_channels: u64,
    /// `Metrics::locality_fastpath_ops` on unit 0.
    fastpath_ops: u64,
    /// Collective-result checksum (must match across placements/modes).
    checksum: u64,
    /// Peak concurrently runnable ranks (pooled rows; 0 otherwise).
    peak_runnable: u64,
    /// Run-slot limit (pooled rows; 0 otherwise).
    slot_limit: u64,
}

fn cfg(units: usize, nodes: usize, placement: &'static str, exec: ExecMode) -> DartConfig {
    let c = DartConfig::hermit(units, nodes)
        .with_pin(PinPolicy::ScatterNode)
        .with_pools(1 << 16, 1 << 20)
        .with_exec(exec, 0);
    match placement {
        "flat" => c,
        "hier" => c.with_hierarchical_collectives(true),
        "fastpath" => c
            .with_hierarchical_collectives(true)
            .with_shmem_windows(true)
            .with_locality_fastpath(true),
        other => unreachable!("unknown placement {other}"),
    }
}

fn measure(units: usize, placement: &'static str, exec: ExecMode, reps: usize) -> Shot {
    let nodes = (units / 16).max(1);
    let out = Mutex::new(Shot::default());
    let t_run = Instant::now();
    run(cfg(units, nodes, placement, exec), |env| {
        let n = env.size();
        let me = env.myid() as usize;
        // Ring neighbour at stride `nodes`: same node under scatter
        // placement (adding the node count preserves `rank % nodes`).
        let right = ((me + nodes) % n) as UnitId;
        let g = env.team_memalloc_aligned(DART_TEAM_ALL, PUT_BYTES as u64).unwrap();
        let mine = vec![me as u64 + 1; RED];
        let mut red = vec![0u64; RED];
        let src = vec![(me & 0xFF) as u8; PUT_BYTES];
        // Warm the locality split (sub-team creation) and the channel
        // table's collective pairs outside the timing.
        env.allreduce(DART_TEAM_ALL, &mine, &mut red, MpiOp::Sum).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        let crossings0 = env.inter_node_messages();
        let mut s = Samples::new();
        let t0 = Instant::now();
        for _ in 0..reps {
            let t = Instant::now();
            env.barrier(DART_TEAM_ALL).unwrap();
            env.allreduce(DART_TEAM_ALL, &mine, &mut red, MpiOp::Sum).unwrap();
            env.put_async(g.with_unit(right), &src).unwrap();
            env.flush_all(g).unwrap();
            env.barrier(DART_TEAM_ALL).unwrap();
            s.push(t.elapsed().as_nanos() as f64);
        }
        let timed = t0.elapsed();
        // The ring is a permutation: exactly one writer per unit.
        let writer = (me + n - nodes) % n;
        let mut got = vec![0u8; PUT_BYTES];
        env.local_read(g.with_unit(me as UnitId), &mut got).unwrap();
        assert!(
            got.iter().all(|&b| b == (writer & 0xFF) as u8),
            "unit {me}: ring put delivered wrong bytes"
        );
        env.barrier(DART_TEAM_ALL).unwrap();
        if me == 0 {
            let (limit, peak) = env.exec_gate_stats().unwrap_or((0, 0));
            *out.lock().unwrap() = Shot {
                units: n as u64,
                nodes: nodes as u64,
                placement,
                exec: match exec {
                    ExecMode::ThreadPerRank => "thread-per-rank",
                    ExecMode::Pooled => "pooled",
                },
                reps: reps as u64,
                ops_per_sec: reps as f64 * n as f64 * OPS_PER_REP / timed.as_secs_f64(),
                modelled_ns: s.median(),
                wall_ms: 0.0, // stamped by the caller around the launch
                node_crossings: env.inter_node_messages() - crossings0,
                active_channels: env.active_channels() as u64,
                fastpath_ops: env.metrics.locality_fastpath_ops.get(),
                checksum: red[0].wrapping_mul(0x9E37_79B9).wrapping_add(red[RED - 1]),
                peak_runnable: peak as u64,
                slot_limit: limit as u64,
            };
        }
        env.team_memfree(DART_TEAM_ALL, g).unwrap();
    })
    .unwrap();
    let mut shot = out.into_inner().unwrap();
    shot.wall_ms = t_run.elapsed().as_secs_f64() * 1e3;
    shot
}

fn json_shot(s: &Shot) -> String {
    format!(
        "{{\"units\":{},\"nodes\":{},\"placement\":\"{}\",\"exec\":\"{}\",\"reps\":{},\
         \"ops_per_sec\":{:.1},\"modelled_ns\":{:.1},\"wall_ms\":{:.3},\
         \"node_crossings\":{},\"active_channels\":{},\"fastpath_ops\":{},\"checksum\":{},\
         \"peak_runnable\":{},\"slot_limit\":{}}}",
        s.units,
        s.nodes,
        s.placement,
        s.exec,
        s.reps,
        s.ops_per_sec,
        s.modelled_ns,
        s.wall_ms,
        s.node_crossings,
        s.active_channels,
        s.fastpath_ops,
        s.checksum,
        s.peak_runnable,
        s.slot_limit
    )
}

fn main() {
    let quick = quick_mode();
    let reps = if quick { 3 } else { 10 };
    let max_units: usize = std::env::var("DART_SCALE_MAX_UNITS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(*SIZES.last().unwrap());
    let sizes: Vec<usize> = SIZES.iter().copied().filter(|&u| u <= max_units).collect();
    assert!(!sizes.is_empty(), "DART_SCALE_MAX_UNITS={max_units} leaves nothing to sweep");

    println!("==== §Scale — weak scaling, 16 units/node, scatter placement ====");
    let mut shots = Vec::new();
    for &units in &sizes {
        for placement in ["flat", "hier", "fastpath"] {
            shots.push(measure(units, placement, ExecMode::Pooled, reps));
        }
    }
    // Execution-mode determinism cross-check at one mid-size point.
    let probe = sizes.iter().copied().find(|&u| u >= 64).unwrap_or(sizes[0]);
    let tpr = measure(probe, "flat", ExecMode::ThreadPerRank, reps);

    println!(
        "\n{:>6} {:>6} {:>9} {:>12} {:>12} {:>10} {:>10} {:>9} {:>9}",
        "units", "nodes", "placement", "ops/s", "modelled", "wall ms", "crossings", "channels",
        "fastpath"
    );
    for s in shots.iter().chain(std::iter::once(&tpr)) {
        println!(
            "{:>6} {:>6} {:>9} {:>12.0} {:>12} {:>10.1} {:>10} {:>9} {:>9}",
            s.units,
            s.nodes,
            s.placement,
            s.ops_per_sec,
            fmt_ns(s.modelled_ns),
            s.wall_ms,
            s.node_crossings,
            s.active_channels,
            s.fastpath_ops
        );
    }

    let find = |units: usize, placement: &str| -> &Shot {
        shots
            .iter()
            .find(|s| s.units == units as u64 && s.placement == placement)
            .expect("row present")
    };

    // Gate 1: bit-identical collective results across placements and
    // across execution modes.
    for &units in &sizes {
        let flat = find(units, "flat");
        assert_eq!(flat.checksum, find(units, "hier").checksum, "{units}: hier result differs");
        assert_eq!(
            flat.checksum,
            find(units, "fastpath").checksum,
            "{units}: fastpath result differs"
        );
    }
    assert_eq!(
        find(probe, "flat").checksum,
        tpr.checksum,
        "{probe}: pooled and thread-per-rank worlds disagree"
    );

    // Gate 2: channel-table sparsity — logarithmic schedules populate
    // O(units · log units) directed pairs, nowhere near units².
    for s in &shots {
        if s.units >= 256 {
            assert!(
                s.active_channels < s.units * 40,
                "{} units/{}: {} active channels — channel table is not sparse",
                s.units,
                s.placement,
                s.active_channels
            );
        }
    }

    // Gate 3: the hierarchical placements' node-crossing advantage, and
    // its growth with node count. Snapshot skew from barrier tails is at
    // most a few messages, far inside the 2× / 1.5× slack.
    let multi: Vec<usize> = sizes.iter().copied().filter(|&u| u / 16 > 1).collect();
    let mut prev_saved = 0u64;
    for &units in &multi {
        let flat = find(units, "flat");
        let hier = find(units, "hier");
        assert!(
            2 * hier.node_crossings < flat.node_crossings,
            "{units}: hier crossings {} not well below flat {}",
            hier.node_crossings,
            flat.node_crossings
        );
        let saved = flat.node_crossings - hier.node_crossings;
        assert!(
            2 * saved > 3 * prev_saved,
            "{units}: crossings saved {saved} did not grow over {prev_saved}"
        );
        prev_saved = saved;
    }
    if let (Some(&lo), Some(&hi)) = (multi.first(), multi.last()) {
        println!(
            "\ncrossings saved by hier: {} at {} nodes → {} at {} nodes",
            find(lo, "flat").node_crossings - find(lo, "hier").node_crossings,
            lo / 16,
            find(hi, "flat").node_crossings - find(hi, "hier").node_crossings,
            hi / 16
        );
    }

    // Gate 4: the intra-node ring puts ride the zero-copy fast path only
    // when it is on.
    for &units in &sizes {
        assert!(find(units, "fastpath").fastpath_ops > 0, "{units}: fast path never hit");
        assert_eq!(find(units, "flat").fastpath_ops, 0, "{units}: fast path hit with knob off");
    }

    // Gate 5: pooled rows stayed inside the run-slot bound, and quick
    // mode meets the wall-clock budget (the acceptance criterion is
    // < 30 s at 1024 units).
    for s in &shots {
        assert!(
            s.peak_runnable <= s.slot_limit && s.slot_limit > 0,
            "{} units: peak runnable {} vs slot limit {}",
            s.units,
            s.peak_runnable,
            s.slot_limit
        );
        if quick {
            assert!(
                s.wall_ms < 30_000.0,
                "{} units/{}: {} ms blows the quick-mode wall budget",
                s.units,
                s.placement,
                s.wall_ms
            );
        }
    }

    let rows: Vec<String> = shots.iter().chain(std::iter::once(&tpr)).map(json_shot).collect();
    let json = format!(
        "{{\"bench\":\"perf_scale\",\"reps\":{reps},\"max_units\":{},\"results\":[{}]}}",
        sizes.last().unwrap(),
        rows.join(",")
    );
    std::fs::write("BENCH_scale.json", format!("{json}\n")).expect("write BENCH_scale.json");
    println!("\nwrote BENCH_scale.json");
}
