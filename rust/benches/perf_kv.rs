//! §KV — the lock-free atomics hot path under a zipfian key-value load.
//!
//! Drives `apps::kvstore` — millions of simulated GET/SET requests against
//! one `dash::HashMap` — through its three write disciplines (lock-free
//! CAS, MCS lock per bucket, owner-computes sharding) on a grid of
//! placements and execution modes, writing `BENCH_kv.json`:
//!
//! - **placement** — `block` packs every unit on one node (all atomics
//!   ride the intra-node CPU-atomic fast path) vs `scatter` round-robins
//!   over 8 nodes (most traffic crosses the modelled interconnect);
//! - **exec** — `thread-per-rank` vs `pooled` run-slot scheduling, which
//!   must not change any result.
//!
//! Deterministic correctness gates, asserted here so CI catches atomicity
//! regressions: all three backends (and both exec modes) produce the
//! bit-identical final store checksum, the lock-free backend strictly
//! outruns the MCS-lock backend on the contended mix, and block placement
//! actually exercises the fast path (`atomic_fastpath_ops > 0`).

use dart::apps::kvstore::{run_kv, KvBackend, KvConfig};
use dart::bench_util::{fmt_ns, quick_mode, Samples};
use dart::dart::{run, DartConfig, DART_TEAM_ALL};
use dart::mpisim::ExecMode;
use dart::simnet::PinPolicy;
use std::sync::Mutex;
use std::time::Instant;

/// One measured configuration (uniform row schema for the JSON).
#[derive(Clone, Default)]
struct Shot {
    backend: &'static str,
    placement: &'static str,
    exec: &'static str,
    units: u64,
    /// Total operations per repetition (team-wide).
    ops: u64,
    /// Median throughput over the repetitions.
    ops_per_sec: f64,
    /// Modelled per-op latency percentiles (worst unit).
    p50_ns: f64,
    p95_ns: f64,
    p99_ns: f64,
    /// Lost CAS slot claims (team total, lock-free backend contention).
    cas_retries: u64,
    /// Runtime atomic ops issued during the run (team total).
    atomic_ops: u64,
    /// Atomics completed on the CPU-atomic fast path (team total).
    fastpath_ops: u64,
    /// Final store content checksum — the cross-backend oracle.
    checksum: u64,
    /// Median repetition wall-clock in ms.
    wall_ms: f64,
}

fn cfg(units: usize, placement: &'static str, exec: ExecMode) -> DartConfig {
    let (nodes, pin) = match placement {
        "block" => (1, PinPolicy::Block),
        _ => (8, PinPolicy::ScatterNode),
    };
    DartConfig::hermit(units, nodes)
        .with_pin(pin)
        .with_pools(1 << 16, 1 << 21)
        .with_shmem_windows(true)
        .with_exec(exec, 0)
}

fn kv_cfg(units: usize, quick: bool) -> KvConfig {
    // Load factor stays ≤ 1/8 of total slots: keys ≤ capacity / 8.
    let (keys, ops_per_unit) = if quick {
        (256, 512)
    } else if units >= 256 {
        (4096, 4096)
    } else {
        (4096, 8192)
    };
    KvConfig {
        keys,
        ops_per_unit,
        get_percent: 75,
        zipf_exponent: 0.99,
        seed: 0x5EED_CAFE ^ units as u64,
        slots_per_unit: ((keys * 8).div_ceil(units)).max(64),
        locks: 64,
        flush_every: 32,
        team: DART_TEAM_ALL,
    }
}

fn exec_label(exec: ExecMode) -> &'static str {
    match exec {
        ExecMode::ThreadPerRank => "thread-per-rank",
        ExecMode::Pooled => "pooled",
    }
}

fn measure(
    units: usize,
    placement: &'static str,
    exec: ExecMode,
    backend: KvBackend,
    reps: usize,
) -> Shot {
    let kv = kv_cfg(units, quick_mode());
    let out = Mutex::new(Shot::default());
    run(cfg(units, placement, exec), |env| {
        let mut s = Samples::new();
        let mut shot = Shot::default();
        for rep in 0..reps {
            env.barrier(DART_TEAM_ALL).unwrap();
            let t = Instant::now();
            let report = run_kv(env, &kv, backend).unwrap();
            let wall = t.elapsed();
            s.push(wall.as_secs_f64() * 1e3);
            if env.myid() == 0 {
                if rep > 0 {
                    assert_eq!(
                        shot.checksum, report.checksum,
                        "{}/{placement}: checksum changed between repetitions",
                        backend.label()
                    );
                }
                shot = Shot {
                    backend: backend.label(),
                    placement,
                    exec: exec_label(exec),
                    units: units as u64,
                    ops: report.ops,
                    ops_per_sec: 0.0,
                    p50_ns: report.p50_ns,
                    p95_ns: report.p95_ns,
                    p99_ns: report.p99_ns,
                    cas_retries: report.cas_retries,
                    atomic_ops: report.atomic_ops,
                    fastpath_ops: report.atomic_fastpath_ops,
                    checksum: report.checksum,
                    wall_ms: 0.0,
                };
            }
        }
        if env.myid() == 0 {
            shot.wall_ms = s.median();
            shot.ops_per_sec = shot.ops as f64 / (s.median() / 1e3);
            *out.lock().unwrap() = shot;
        }
        env.barrier(DART_TEAM_ALL).unwrap();
    })
    .unwrap();
    out.into_inner().unwrap()
}

fn json_shot(s: &Shot) -> String {
    format!(
        "{{\"backend\":\"{}\",\"placement\":\"{}\",\"exec\":\"{}\",\"units\":{},\"ops\":{},\
         \"ops_per_sec\":{:.1},\"p50_ns\":{:.1},\"p95_ns\":{:.1},\"p99_ns\":{:.1},\
         \"cas_retries\":{},\"atomic_ops\":{},\"fastpath_ops\":{},\"checksum\":{},\
         \"wall_ms\":{:.3}}}",
        s.backend,
        s.placement,
        s.exec,
        s.units,
        s.ops,
        s.ops_per_sec,
        s.p50_ns,
        s.p95_ns,
        s.p99_ns,
        s.cas_retries,
        s.atomic_ops,
        s.fastpath_ops,
        s.checksum,
        s.wall_ms
    )
}

fn main() {
    let quick = quick_mode();
    let reps = if quick { 2 } else { 3 };
    let unit_grid: &[usize] = if quick { &[8] } else { &[64, 256] };
    let max_units = *unit_grid.last().unwrap();
    println!("==== §KV — lock-free vs MCS-lock vs owner-computes key-value store ====");

    let mut shots = Vec::new();
    for &units in unit_grid {
        for placement in ["block", "scatter"] {
            for exec in [ExecMode::ThreadPerRank, ExecMode::Pooled] {
                for backend in KvBackend::ALL {
                    shots.push(measure(units, placement, exec, backend, reps));
                }
            }
        }
    }

    println!(
        "\n{:>6} {:>8} {:>16} {:>6} {:>14} {:>10} {:>10} {:>12} {:>10}",
        "bkend", "place", "exec", "units", "ops/s", "p50", "p99", "cas_retry", "fastpath"
    );
    for s in &shots {
        println!(
            "{:>6} {:>8} {:>16} {:>6} {:>14.0} {:>10} {:>10} {:>12} {:>10}",
            s.backend,
            s.placement,
            s.exec,
            s.units,
            s.ops_per_sec,
            fmt_ns(s.p50_ns),
            fmt_ns(s.p99_ns),
            s.cas_retries,
            s.fastpath_ops
        );
    }

    // --- correctness gates (deterministic — safe to assert in CI) -------
    // 1. The final store contents are a pure function of the op streams:
    //    every backend, placement, and exec mode at one unit count must
    //    agree bit-for-bit.
    for &units in unit_grid {
        let group: Vec<&Shot> = shots.iter().filter(|s| s.units == units as u64).collect();
        for s in &group[1..] {
            assert_eq!(
                group[0].checksum, s.checksum,
                "{units} units: {}/{}/{} disagrees with {}/{}/{} on the final store",
                s.backend, s.placement, s.exec, group[0].backend, group[0].placement,
                group[0].exec
            );
        }
    }
    // 2. Lock-free beats the MCS-lock discipline on the contended mix, in
    //    every cell of the grid.
    for mcs in shots.iter().filter(|s| s.backend == "mcs") {
        let cas = shots
            .iter()
            .find(|s| {
                s.backend == "cas"
                    && s.placement == mcs.placement
                    && s.exec == mcs.exec
                    && s.units == mcs.units
            })
            .unwrap();
        assert!(
            cas.ops_per_sec > mcs.ops_per_sec,
            "{}/{}/{} units: lock-free {} ops/s did not beat MCS {} ops/s",
            mcs.placement,
            mcs.exec,
            mcs.units,
            cas.ops_per_sec,
            mcs.ops_per_sec
        );
        println!(
            "{:>8}/{:<16} {:>4} units: lock-free/MCS speedup {:.2}×",
            mcs.placement,
            mcs.exec,
            mcs.units,
            cas.ops_per_sec / mcs.ops_per_sec
        );
    }
    // 3. Single-node placement actually exercises the CPU-atomic fast path.
    for s in shots.iter().filter(|s| s.backend == "cas" && s.placement == "block") {
        assert!(
            s.fastpath_ops > 0,
            "block placement issued no fast-path atomics ({}/{} units)",
            s.exec,
            s.units
        );
    }

    let rows: Vec<String> = shots.iter().map(json_shot).collect();
    let json = format!(
        "{{\"bench\":\"perf_kv\",\"reps\":{reps},\"max_units\":{max_units},\"results\":[{}]}}",
        rows.join(",")
    );
    std::fs::write("BENCH_kv.json", format!("{json}\n")).expect("write BENCH_kv.json");
    println!("\nwrote BENCH_kv.json");
}
