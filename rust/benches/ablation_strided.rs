//! Ablation A5 — strided (column-halo-shaped) transfers: the engine's
//! single-request **vector** path vs the per-block loop it replaced.
//!
//! The access shape is a boundary column of a row-major `f32` grid:
//! `count` blocks of 8 bytes, one per row, `stride` = 64 bytes. The
//! vector path ([`dart::dart::DartEnv::get_strided`]) moves the whole
//! pattern as one RMA request with one protocol handshake; the per-block
//! baseline issues `count` independent requests (what
//! `put_strided`/`get_strided` did before the engine refactor, and what
//! `stencil2d` paid per column halo per iteration).
//!
//! Expected shape: the two paths pay the same bandwidth term, so the gap
//! is `(count − 1)` per-message overheads — growing linearly with the
//! block count and widest on the inter-node tier, where per-message costs
//! are most expensive in the calibrated model.

use dart::bench_util::{paper_placements, print_comparison_table, Samples};
use dart::dart::{run, DartConfig, DartHandle, DART_TEAM_ALL};
use dart::simnet::PinPolicy;
use std::sync::Mutex;
use std::time::Instant;

const BLOCK: usize = 8; // bytes per block (one f64-sized grid element)
const STRIDE: u64 = 64; // bytes between remote block starts (row pitch)
const REPS: usize = 64;

fn block_counts() -> Vec<usize> {
    vec![1, 2, 4, 8, 16, 32, 64, 128, 256]
}

/// Median completion time of a `count`-block strided get, per path.
fn measure(pin: PinPolicy, vector_path: bool, counts: &[usize]) -> Vec<(usize, f64)> {
    let rows = Mutex::new(Vec::new());
    let cfg = DartConfig::hermit(2, 2).with_pin(pin);
    run(cfg, |env| {
        let g = env.team_memalloc_aligned(DART_TEAM_ALL, 1 << 16).unwrap();
        let target = g.with_unit(1);
        for &count in counts {
            let mut dst = vec![0u8; count * BLOCK];
            env.barrier(DART_TEAM_ALL).unwrap();
            if env.myid() == 0 {
                let mut s = Samples::new();
                for _ in 0..REPS {
                    let t = Instant::now();
                    if vector_path {
                        let h = env
                            .get_strided(target, &mut dst, count, BLOCK, STRIDE)
                            .unwrap();
                        env.wait(h).unwrap();
                    } else {
                        // The pre-engine formulation: one request per block.
                        let mut handles: Vec<DartHandle> = Vec::with_capacity(count);
                        for (i, chunk) in dst.chunks_exact_mut(BLOCK).enumerate() {
                            handles.push(
                                env.get(target.add(i as u64 * STRIDE), chunk).unwrap(),
                            );
                        }
                        env.waitall(handles).unwrap();
                    }
                    s.push(t.elapsed().as_nanos() as f64);
                }
                rows.lock().unwrap().push((count, s.median()));
            }
            env.barrier(DART_TEAM_ALL).unwrap();
        }
        env.team_memfree(DART_TEAM_ALL, g).unwrap();
    })
    .unwrap();
    rows.into_inner().unwrap()
}

fn main() {
    println!("==== Ablation A5 — strided column transfers: vector vs per-block ====");
    println!(
        "(blocking strided get of N × {BLOCK} B blocks, stride {STRIDE} B; median of {REPS} reps; \
         table x-axis = block count)"
    );
    let counts = block_counts();
    for (tier, pin) in paper_placements() {
        let vector = measure(pin.clone(), true, &counts);
        let blocks = measure(pin, false, &counts);
        let rows: Vec<(usize, f64, f64)> = vector
            .iter()
            .zip(&blocks)
            .map(|(&(n, v), &(_, b))| (n, v, b))
            .collect();
        print_comparison_table(&format!("A5 — {tier}"), "ns", ("vector", "per-block"), &rows);
        let wins = rows.iter().filter(|&&(n, v, b)| n >= 4 && v < b).count();
        let total = rows.iter().filter(|&&(n, _, _)| n >= 4).count();
        println!("vector faster at {wins}/{total} sizes ≥ 4 blocks  [{tier}]");
    }
    println!("\nExpected: vector ≤ per-block everywhere, gap ∝ block count (one handshake vs N).");
}
