//! §DYN — the dynamic half of the global memory model, measured.
//!
//! Four scenario families over the `memattach` subsystem, writing
//! `BENCH_dynamic.json`:
//!
//! - **attach / detach** — latency of the non-collective
//!   `memattach`/`memdetach` pair (64 KiB regions), the dynamic
//!   counterpart of the paper's collective allocation path;
//! - **put/get overhead** — blocking put/get to a *remote* unit's
//!   symmetric allocation vs its dynamically attached region, segment
//!   cache on. The dynamic path must stay within a bounded factor of the
//!   symmetric path (asserted): after the first resolution both are one
//!   cache hit + the same window op;
//! - **vector growth** — `dash::Vector` grown through ≥ 3 capacity
//!   doublings by collective pushes; reports redistribution bandwidth and
//!   asserts the grown vector is **bit-identical** to a preallocated
//!   `dash::Array` of the final capacity filled with the same values;
//! - **work queue** — the `apps::wqueue` task farm under block and
//!   scatter placements; throughput plus the exactly-once checksum gate
//!   against the sequential reference.

use dart::apps::wqueue::{reference_result, run_distributed, WqueueConfig};
use dart::bench_util::{bandwidth_mb_s, fmt_ns, quick_mode, Samples};
use dart::dart::{run, DartConfig, GlobalPtr, DART_TEAM_ALL};
use dart::dash::{Array, Pattern, Vector};
use dart::mpisim::ExecMode;
use dart::simnet::PinPolicy;
use std::sync::Mutex;
use std::time::Instant;

/// One measured configuration (uniform row schema for the JSON).
#[derive(Clone, Default)]
struct Shot {
    scenario: &'static str,
    placement: &'static str,
    units: u64,
    /// Operations per repetition (ops, elements, or tasks — see scenario).
    ops: u64,
    /// Median per-op latency (0 where throughput is the story).
    ns_per_op: f64,
    /// Median throughput.
    ops_per_sec: f64,
    /// Bytes the scenario moved (region size, payload, redistribution).
    bytes: u64,
    /// Bandwidth where bytes/wall is meaningful, else 0.
    bandwidth_mb_s: f64,
    /// Scenario checksum (cross-run / cross-structure oracle; 0 if n/a).
    checksum: u64,
    /// Median repetition wall-clock in ms.
    wall_ms: f64,
}

fn cfg(units: usize, placement: &'static str) -> DartConfig {
    let (nodes, pin) = match placement {
        "block" => (1, PinPolicy::Block),
        _ => (8, PinPolicy::ScatterNode),
    };
    DartConfig::hermit(units, nodes)
        .with_pin(pin)
        .with_pools(1 << 16, 1 << 21)
        .with_shmem_windows(true)
        .with_segment_cache(true)
        .with_exec(ExecMode::ThreadPerRank, 0)
}

/// Deterministic element payload for the vector/array comparison.
fn elem(g: u64, seed: u64) -> u64 {
    (g ^ seed).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ (g >> 7)
}

// ---------------------------------------------------------------------------
// attach / detach latency
// ---------------------------------------------------------------------------

fn measure_attach(units: usize, reps: usize, quick: bool) -> Vec<Shot> {
    let region = 64 * 1024u64;
    let pairs = if quick { 64 } else { 512 };
    let out = Mutex::new(Vec::new());
    run(cfg(units, "block"), |env| {
        let mut attach = Samples::new();
        let mut detach = Samples::new();
        for _ in 0..reps {
            if env.myid() == 0 {
                let mut a_ns = 0.0;
                let mut d_ns = 0.0;
                for _ in 0..pairs {
                    let t = Instant::now();
                    let g = env.memattach(region).unwrap();
                    a_ns += t.elapsed().as_nanos() as f64;
                    let t = Instant::now();
                    env.memdetach(g).unwrap();
                    d_ns += t.elapsed().as_nanos() as f64;
                }
                attach.push(a_ns / pairs as f64);
                detach.push(d_ns / pairs as f64);
            }
            env.barrier(DART_TEAM_ALL).unwrap();
        }
        if env.myid() == 0 {
            let shot = |scenario, s: &Samples| Shot {
                scenario,
                placement: "block",
                units: units as u64,
                ops: pairs,
                ns_per_op: s.median(),
                ops_per_sec: 1e9 / s.median(),
                bytes: region,
                bandwidth_mb_s: 0.0,
                checksum: 0,
                wall_ms: s.median() * pairs as f64 / 1e6,
            };
            let mut o = out.lock().unwrap();
            o.push(shot("attach", &attach));
            o.push(shot("detach", &detach));
        }
    })
    .unwrap();
    out.into_inner().unwrap()
}

// ---------------------------------------------------------------------------
// dynamic vs symmetric put/get overhead (cache on)
// ---------------------------------------------------------------------------

fn measure_overhead(units: usize, reps: usize, quick: bool) -> Vec<Shot> {
    let ops = if quick { 512u64 } else { 4096 };
    let out = Mutex::new(Vec::new());
    run(cfg(units, "scatter"), |env| {
        let p = env.size();
        // Symmetric target: remote half of a collective allocation.
        let sym = env.team_memalloc_aligned(DART_TEAM_ALL, 64).unwrap();
        // Dynamic target: every unit attaches, directory allgathered.
        let mine = env.memattach(64).unwrap();
        let mut recv = vec![0u8; 16 * p];
        env.allgather(DART_TEAM_ALL, &mine.to_bits().to_ne_bytes(), &mut recv).unwrap();
        let dir: Vec<GlobalPtr> = recv
            .chunks_exact(16)
            .map(|c| GlobalPtr::from_bits(u128::from_ne_bytes(c.try_into().unwrap())))
            .collect();
        env.barrier(DART_TEAM_ALL).unwrap();

        if env.myid() == 0 {
            let victim = p - 1; // scatter placement ⇒ off-node
            let targets = [("sym", sym.with_unit(victim as i32)), ("dyn", dir[victim])];
            let mut medians = Vec::new();
            for (kind, gptr) in targets {
                let mut put = Samples::new();
                let mut get = Samples::new();
                let mut buf = [0u8; 8];
                // Warm the segment cache: overhead is the steady state.
                env.put_blocking(gptr, &7u64.to_ne_bytes()).unwrap();
                for _ in 0..reps {
                    let t = Instant::now();
                    for i in 0..ops {
                        env.put_blocking(gptr, &i.to_ne_bytes()).unwrap();
                    }
                    put.push(t.elapsed().as_nanos() as f64 / ops as f64);
                    let t = Instant::now();
                    for _ in 0..ops {
                        env.get_blocking(gptr, &mut buf).unwrap();
                    }
                    get.push(t.elapsed().as_nanos() as f64 / ops as f64);
                }
                let readback = u64::from_ne_bytes(buf);
                assert_eq!(readback, ops - 1, "{kind}: lost the last put");
                for (dir_label, s) in [("put", &put), ("get", &get)] {
                    medians.push((kind, dir_label, s.median()));
                    out.lock().unwrap().push(Shot {
                        scenario: match (dir_label, kind) {
                            ("put", "sym") => "put_sym",
                            ("put", "dyn") => "put_dyn",
                            ("get", "sym") => "get_sym",
                            _ => "get_dyn",
                        },
                        placement: "scatter",
                        units: units as u64,
                        ops,
                        ns_per_op: s.median(),
                        ops_per_sec: 1e9 / s.median(),
                        bytes: 8,
                        bandwidth_mb_s: bandwidth_mb_s(8, s.median()),
                        checksum: readback,
                        wall_ms: s.median() * ops as f64 / 1e6,
                    });
                }
            }
            // The bounded-overhead gate: with the cache warm, the dynamic
            // path is one generation check away from the symmetric path.
            for want in ["put", "get"] {
                let sym_ns = medians.iter().find(|m| m.0 == "sym" && m.1 == want).unwrap().2;
                let dyn_ns = medians.iter().find(|m| m.0 == "dyn" && m.1 == want).unwrap().2;
                assert!(
                    dyn_ns <= sym_ns * 4.0 + 5_000.0,
                    "dynamic {want} {dyn_ns:.0} ns/op not within bounded overhead of \
                     symmetric {sym_ns:.0} ns/op (cache on)"
                );
                println!(
                    "  {want}: symmetric {} vs dynamic {} per op ({:.2}× overhead)",
                    fmt_ns(sym_ns),
                    fmt_ns(dyn_ns),
                    dyn_ns / sym_ns
                );
            }
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        env.memdetach(mine).unwrap();
        env.team_memfree(DART_TEAM_ALL, sym).unwrap();
    })
    .unwrap();
    out.into_inner().unwrap()
}

// ---------------------------------------------------------------------------
// vector growth bandwidth + bit-equality vs preallocated Array
// ---------------------------------------------------------------------------

fn measure_vector_growth(units: usize, reps: usize, quick: bool) -> Vec<Shot> {
    // 16 collective pushes of one element per member: capacity p → 16p,
    // four doublings (the acceptance floor is three).
    let pushes = if quick { 16 } else { 32 };
    let seed = 0xD1_4A_11_0Cu64;
    let out = Mutex::new(Vec::new());
    run(cfg(units, "block"), |env| {
        let p = env.size();
        let team = DART_TEAM_ALL;
        let mut walls = Samples::new();
        let mut shot = Shot::default();
        for _ in 0..reps {
            let redist_before = env.metrics.dash_redist_bytes.get();
            let mut v = Vector::<u64>::with_capacity(env, team, p).unwrap();
            let cap0 = v.capacity();
            env.barrier(team).unwrap();
            let t = Instant::now();
            for _ in 0..pushes {
                let base = v.len().unwrap();
                let me = env.team_myid(team).unwrap();
                v.push(elem((base + me) as u64, seed)).unwrap();
            }
            let wall = t.elapsed();
            walls.push(wall.as_secs_f64() * 1e3);

            let n = v.len().unwrap();
            let final_cap = v.capacity();
            let doublings = (final_cap / cap0).ilog2();
            assert!(
                doublings >= 3,
                "grew {cap0} → {final_cap}: only {doublings} doublings (need ≥ 3)"
            );
            // The oracle: a preallocated Array of the final capacity with
            // the same BLOCKED pattern and the same fill.
            let arr =
                Array::<u64>::new(env, team, Pattern::blocked(final_cap, p).unwrap()).unwrap();
            let me = env.team_myid(team).unwrap();
            arr.with_local(|loc| {
                for (i, slot) in loc.iter_mut().enumerate() {
                    let g = arr.pattern().local_to_global(me, i);
                    *slot = if g < n { elem(g as u64, seed) } else { 0 };
                }
            })
            .unwrap();
            env.barrier(team).unwrap();
            let got = v.read_local().unwrap();
            let want = arr.read_local().unwrap();
            assert_eq!(
                got, want,
                "unit {me}: grown vector differs from preallocated array"
            );
            let checksum = (0..n as u64).fold(0u64, |acc, g| acc ^ elem(g, seed));
            let redist = env.metrics.dash_redist_bytes.get() - redist_before;
            if env.myid() == 0 {
                shot = Shot {
                    scenario: "vector_growth",
                    placement: "block",
                    units: units as u64,
                    ops: n as u64,
                    ns_per_op: 0.0,
                    ops_per_sec: 0.0,
                    bytes: redist,
                    bandwidth_mb_s: 0.0,
                    checksum,
                    wall_ms: 0.0,
                };
            }
            arr.free().unwrap();
            v.free().unwrap();
        }
        if env.myid() == 0 {
            shot.wall_ms = walls.median();
            shot.ops_per_sec = shot.ops as f64 / (walls.median() / 1e3);
            shot.bandwidth_mb_s = bandwidth_mb_s(shot.bytes as usize, walls.median() * 1e6);
            out.lock().unwrap().push(shot);
        }
        env.barrier(team).unwrap();
    })
    .unwrap();
    out.into_inner().unwrap()
}

// ---------------------------------------------------------------------------
// work-queue throughput under block and scatter placement
// ---------------------------------------------------------------------------

fn measure_wqueue(units: usize, placement: &'static str, reps: usize, quick: bool) -> Shot {
    let wq = WqueueConfig {
        tasks: if quick { 512 } else { 4096 },
        ring_capacity: 32,
        seed: 0xFA12_07A5 ^ units as u64,
        team: DART_TEAM_ALL,
    };
    let want = reference_result(&wq);
    let out = Mutex::new(Shot::default());
    run(cfg(units, placement), |env| {
        let mut walls = Samples::new();
        let mut steals = 0u64;
        for _ in 0..reps {
            env.barrier(DART_TEAM_ALL).unwrap();
            let t = Instant::now();
            let report = run_distributed(env, &wq).unwrap();
            walls.push(t.elapsed().as_secs_f64() * 1e3);
            assert_eq!(report.retired, wq.tasks as u64, "{placement}: lost tasks");
            assert_eq!(report.checksum, want, "{placement}: checksum mismatch");
            steals = env.metrics.wq_steals.get();
        }
        if env.myid() == 0 {
            *out.lock().unwrap() = Shot {
                scenario: "wq_throughput",
                placement,
                units: units as u64,
                ops: wq.tasks as u64,
                ns_per_op: walls.median() * 1e6 / wq.tasks as f64,
                ops_per_sec: wq.tasks as f64 / (walls.median() / 1e3),
                bytes: 8 * wq.tasks as u64,
                bandwidth_mb_s: 0.0,
                checksum: want,
                wall_ms: walls.median(),
            };
            // Steals are this unit's count; the skewed split guarantees
            // *someone* stole, which the chaos invariant checks team-wide.
            let _ = steals;
        }
        env.barrier(DART_TEAM_ALL).unwrap();
    })
    .unwrap();
    out.into_inner().unwrap()
}

fn json_shot(s: &Shot) -> String {
    format!(
        "{{\"scenario\":\"{}\",\"placement\":\"{}\",\"units\":{},\"ops\":{},\
         \"ns_per_op\":{:.1},\"ops_per_sec\":{:.1},\"bytes\":{},\
         \"bandwidth_mb_s\":{:.3},\"checksum\":{},\"wall_ms\":{:.3}}}",
        s.scenario,
        s.placement,
        s.units,
        s.ops,
        s.ns_per_op,
        s.ops_per_sec,
        s.bytes,
        s.bandwidth_mb_s,
        s.checksum,
        s.wall_ms
    )
}

fn main() {
    let quick = quick_mode();
    let reps = if quick { 2 } else { 3 };
    let units = if quick { 4 } else { 8 };
    println!("==== §DYN — dynamic global memory: attach, overhead, growth, queue ====");

    let mut shots = Vec::new();
    shots.extend(measure_attach(units, reps, quick));
    shots.extend(measure_overhead(units, reps, quick));
    shots.extend(measure_vector_growth(units, reps, quick));
    for placement in ["block", "scatter"] {
        shots.push(measure_wqueue(units, placement, reps, quick));
    }

    println!(
        "\n{:>14} {:>8} {:>6} {:>8} {:>10} {:>14} {:>12} {:>10}",
        "scenario", "place", "units", "ops", "ns/op", "ops/s", "MB/s", "wall_ms"
    );
    for s in &shots {
        println!(
            "{:>14} {:>8} {:>6} {:>8} {:>10} {:>14.0} {:>12.1} {:>10.3}",
            s.scenario,
            s.placement,
            s.units,
            s.ops,
            fmt_ns(s.ns_per_op),
            s.ops_per_sec,
            s.bandwidth_mb_s,
            s.wall_ms
        );
    }

    let rows: Vec<String> = shots.iter().map(json_shot).collect();
    let json = format!(
        "{{\"bench\":\"perf_dynamic\",\"reps\":{reps},\"max_units\":{units},\"results\":[{}]}}",
        rows.join(",")
    );
    std::fs::write("BENCH_dynamic.json", format!("{json}\n")).expect("write BENCH_dynamic.json");
    println!("\nwrote BENCH_dynamic.json");
}
