//! §Async progress — communication/computation overlap across progress
//! modes and placements.
//!
//! The follow-up paper's question (Zhou & Gracia, "Asynchronous progress
//! design for a MPI-based PGAS one-sided communication system"): who pays
//! for completion? Two measured scenarios per `(progress mode, placement)`
//! configuration, 2 units each:
//!
//! - **RMA phase**: unit 0 issues a batch of deferred-completion puts
//!   (`put_async`), "computes" for a fixed window (spinning, with
//!   cooperative polls in `Polling` mode), then pays `flush_all`. The
//!   engine-retired share of the traffic is the *overlap efficiency*
//!   (`overlap_bytes / async_bytes` from [`dart::dart::Metrics`]): `0` in
//!   `Caller` mode by construction, `→1` when the engine retires the whole
//!   batch in the background.
//! - **Collective phase**: both units run a pipelined nonblocking
//!   allreduce (`allreduce_async`) across the same compute window and the
//!   *wait* is timed. In `Caller` mode the reduction + fan-out transfer
//!   only start inside the wait; in `Thread`/`Polling` modes they ran
//!   during the compute window, so the wait shrinks toward zero.
//!
//! Results print as a table and land in `BENCH_overlap.json`, including
//! the cost side of the ablation: total engine wakeups and the modelled
//! nanoseconds charged for them (`progress_tick_ns`).
//!
//! A **straggler series** (`"faults":"straggler"` rows) reruns the
//! `Caller`/`Polling` pair on the inter-node placement with one node
//! dragging every transfer it touches by 4× (single-class
//! [`FaultPlan`]): overlap is *more* valuable when the wire is slow, so
//! `Polling` must still retire traffic in the background while `Caller`
//! stays at zero overlap and pays the whole dragged wait itself.

use dart::bench_util::{fmt_ns, quick_mode, Samples};
use dart::dart::{run, DartConfig, ProgressMode, DART_TEAM_ALL};
use dart::mpisim::MpiOp;
use dart::simnet::cost::spin_for;
use dart::simnet::{FaultPlan, PinPolicy};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// One measured configuration.
#[derive(Clone, Default)]
struct Shot {
    mode: &'static str,
    placement: &'static str,
    /// Fault-plan label: `"none"` for the clean series, `"straggler"`
    /// for the one-slow-node ablation.
    faults: &'static str,
    /// RMA phase: bytes issued as deferred-completion puts.
    async_bytes: u64,
    /// RMA phase: bytes retired by the progress engine (overlap achieved).
    overlap_bytes: u64,
    /// RMA phase: median ns spent inside `flush_all`.
    flush_ns: f64,
    /// Collective phase: median ns spent inside the allreduce wait.
    coll_wait_ns: f64,
    /// Engine wakeups over the whole launch (thread + polls).
    engine_ticks: u64,
    /// Modelled ns charged for those wakeups.
    tick_ns_charged: u64,
}

impl Shot {
    fn overlap_efficiency(&self) -> f64 {
        if self.async_bytes == 0 {
            0.0
        } else {
            self.overlap_bytes as f64 / self.async_bytes as f64
        }
    }
}

/// Spin for `window`, polling the engine roughly every `poll_every` when
/// in `Polling` mode (other modes just spin — that is the point).
fn compute_window(env: &dart::dart::DartEnv, mode: ProgressMode, window: Duration) {
    let start = Instant::now();
    let slice = Duration::from_micros(20);
    while start.elapsed() < window {
        spin_for(slice.min(window.saturating_sub(start.elapsed())));
        if mode == ProgressMode::Polling {
            env.progress_poll();
        }
    }
}

fn measure(
    mode: ProgressMode,
    placement: &'static str,
    pin: PinPolicy,
    reps: usize,
    faults: Option<(&'static str, FaultPlan)>,
) -> Shot {
    const PUTS: usize = 24;
    const PUT_BYTES: usize = 16 << 10; // 16 KiB, E1 regime
    const WINDOW: Duration = Duration::from_micros(400);
    let out = Mutex::new(Shot::default());
    let mut cfg = DartConfig::hermit(2, 2)
        .with_pin(pin)
        .with_pools(1 << 16, 1 << 20)
        .with_progress_mode(mode);
    let fault_label = match faults {
        Some((label, plan)) => {
            cfg = cfg.with_fault_plan(plan);
            label
        }
        None => "none",
    };
    run(cfg, |env| {
        let g = env.team_memalloc_aligned(DART_TEAM_ALL, (PUTS * PUT_BYTES) as u64).unwrap();
        let src = vec![0xA5u8; PUT_BYTES];
        env.barrier(DART_TEAM_ALL).unwrap();

        // --- RMA phase (unit 0 drives; unit 1 is the passive target).
        let mut flush = Samples::new();
        for _ in 0..reps {
            if env.myid() == 0 {
                for i in 0..PUTS {
                    env.put_async(g.with_unit(1).add((i * PUT_BYTES) as u64), &src).unwrap();
                }
                compute_window(env, mode, WINDOW);
                let t = Instant::now();
                env.flush_all(g).unwrap();
                flush.push(t.elapsed().as_nanos() as f64);
            }
            env.barrier(DART_TEAM_ALL).unwrap();
        }

        // --- collective phase (both units participate).
        let mut coll = Samples::new();
        let mine = vec![env.myid() as f64 + 1.0; 1024];
        let mut reduced = vec![0f64; 1024];
        for _ in 0..reps {
            let h = env
                .allreduce_async(DART_TEAM_ALL, &mine, &mut reduced, MpiOp::Sum)
                .unwrap();
            compute_window(env, mode, WINDOW);
            let t = Instant::now();
            env.coll_wait(h).unwrap();
            coll.push(t.elapsed().as_nanos() as f64);
            env.barrier(DART_TEAM_ALL).unwrap();
        }

        if env.myid() == 0 {
            *out.lock().unwrap() = Shot {
                mode: mode.label(),
                placement,
                faults: fault_label,
                async_bytes: (reps * PUTS * PUT_BYTES) as u64,
                overlap_bytes: env.metrics.overlap_bytes.get(),
                flush_ns: flush.median(),
                coll_wait_ns: coll.median(),
                engine_ticks: env.engine_ticks(),
                tick_ns_charged: env.engine_tick_ns_charged(),
            };
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        env.team_memfree(DART_TEAM_ALL, g).unwrap();
    })
    .unwrap();
    out.into_inner().unwrap()
}

fn json_shot(s: &Shot) -> String {
    format!(
        "{{\"mode\":\"{}\",\"placement\":\"{}\",\"faults\":\"{}\",\"async_bytes\":{},\
         \"overlap_bytes\":{},\"overlap_efficiency\":{:.4},\"flush_ns\":{:.1},\
         \"coll_wait_ns\":{:.1},\"engine_ticks\":{},\"tick_ns_charged\":{}}}",
        s.mode,
        s.placement,
        s.faults,
        s.async_bytes,
        s.overlap_bytes,
        s.overlap_efficiency(),
        s.flush_ns,
        s.coll_wait_ns,
        s.engine_ticks,
        s.tick_ns_charged
    )
}

fn main() {
    let reps = if quick_mode() { 6 } else { 40 };
    println!("==== §Async progress — overlap across progress modes × placements ====");
    let placements: [(&'static str, PinPolicy); 2] =
        [("intra-numa", PinPolicy::Block), ("inter-node", PinPolicy::ScatterNode)];
    let modes = [ProgressMode::Caller, ProgressMode::Polling, ProgressMode::Thread];
    let mut shots = Vec::new();
    for (pname, pin) in placements.iter() {
        for &mode in &modes {
            shots.push(measure(mode, *pname, pin.clone(), reps, None));
        }
    }
    // Straggler series: one node drags every transfer it touches by 4×
    // (all other fault classes quiet, fixed seed) — the ends of the
    // overlap spectrum, on the placement where the wire matters.
    let straggler =
        FaultPlan { straggler_nodes: 1, straggler_factor: 4.0, ..FaultPlan::quiet(0x57A6) };
    for mode in [ProgressMode::Caller, ProgressMode::Polling] {
        let series = Some(("straggler", straggler));
        shots.push(measure(mode, "inter-node", PinPolicy::ScatterNode, reps, series));
    }
    println!(
        "\n{:>10} {:>11} {:>10} {:>12} {:>12} {:>12} {:>14}",
        "mode", "placement", "overlap", "flush", "coll wait", "ticks", "tick ns charged"
    );
    for s in &shots {
        println!(
            "{:>10} {:>11} {:>9.0}% {:>12} {:>12} {:>12} {:>14}",
            s.mode,
            s.placement,
            s.overlap_efficiency() * 100.0,
            fmt_ns(s.flush_ns),
            fmt_ns(s.coll_wait_ns),
            s.engine_ticks,
            s.tick_ns_charged
        );
    }
    println!(
        "\n(expected shape: caller = 0% overlap and the largest collective wait; \
         thread ≈ full overlap at the highest tick charge; polling in between)"
    );

    // Straggler gates: cooperative polling must still retire traffic in
    // the background while a node drags, and caller mode must still pay
    // for everything itself — the overlap ranking survives the fault.
    let dragged = |mode: &str| {
        shots.iter().find(|s| s.faults == "straggler" && s.mode == mode).unwrap()
    };
    let (s_caller, s_polling) = (dragged("caller"), dragged("polling"));
    assert_eq!(s_caller.overlap_bytes, 0, "caller mode overlapped under a straggler");
    assert!(
        s_polling.overlap_bytes > 0,
        "polling retired nothing in the background under a straggler"
    );
    assert!(
        s_polling.coll_wait_ns < s_caller.coll_wait_ns,
        "polling lost its edge under a straggler: polling wait {} vs caller wait {}",
        fmt_ns(s_polling.coll_wait_ns),
        fmt_ns(s_caller.coll_wait_ns)
    );
    println!(
        "straggler: caller wait {} vs polling wait {} at {:.0}% polling overlap",
        fmt_ns(s_caller.coll_wait_ns),
        fmt_ns(s_polling.coll_wait_ns),
        s_polling.overlap_efficiency() * 100.0
    );

    let rows: Vec<String> = shots.iter().map(json_shot).collect();
    let json = format!(
        "{{\"bench\":\"perf_overlap\",\"reps\":{reps},\"put_bytes\":16384,\"puts_per_rep\":24,\
         \"compute_window_us\":400,\"results\":[{}]}}",
        rows.join(",")
    );
    std::fs::write("BENCH_overlap.json", format!("{json}\n")).expect("write BENCH_overlap.json");
    println!("\nwrote BENCH_overlap.json");
}
