//! §Perf — the DART one-sided hot path, software cost only.
//!
//! Measures the per-op cost of the full dereference chain (flags dispatch,
//! teamlist lookup, unit translation, translation-table lookup, epoch
//! check, bounds check) with the network cost model DISABLED, against the
//! raw mpisim window ops. The delta is the pure DART-layer software
//! overhead — the quantity the whole §V evaluation is about.
//!
//! The engine's segment cache is measured in both states (on/off) so the
//! cached-resolution win is tracked per run. Results are printed AND
//! written to `BENCH_hotpath.json` (op latencies + request counts from
//! [`dart::dart::Metrics`]) so the perf trajectory is machine-readable
//! from this PR onward.

use dart::bench_util::{fmt_ns, Samples};
use dart::dart::{run, DartConfig, DART_TEAM_ALL};
use dart::mpisim::{RmaRequest, Win, World, WorldConfig};
use dart::simnet::CostModel;
use std::sync::Mutex;
use std::time::Instant;

const REPS: usize = 20_000;

/// One measured configuration: median ns per op + operation counters.
#[derive(Clone, Default)]
struct Shot {
    put_blocking_ns: f64,
    get_blocking_ns: f64,
    put_dtit_ns: f64,
    puts: u64,
    gets: u64,
    puts_blocking: u64,
    gets_blocking: u64,
    cache_hits: u64,
    cache_misses: u64,
}

fn dart_side(collective: bool, segment_cache: bool) -> Shot {
    let out = Mutex::new(Shot::default());
    let cfg = DartConfig::with_units(2)
        .with_cost(CostModel::zero())
        .with_pools(1 << 16, 1 << 16)
        .with_segment_cache(segment_cache);
    run(cfg, |env| {
        let gptr = if collective {
            env.team_memalloc_aligned(DART_TEAM_ALL, 4096).unwrap().with_unit(1)
        } else {
            // exchange a non-collective pointer from unit 1
            let mine = env.memalloc(4096).unwrap();
            let mut all = vec![0u8; 32];
            env.allgather(DART_TEAM_ALL, &mine.to_bits().to_ne_bytes(), &mut all).unwrap();
            dart::dart::GlobalPtr::from_bits(u128::from_ne_bytes(all[16..32].try_into().unwrap()))
        };
        env.barrier(DART_TEAM_ALL).unwrap();
        if env.myid() == 0 {
            let buf = [42u8; 8];
            let mut dst = [0u8; 8];
            // blocking put
            let mut s_put = Samples::new();
            for _ in 0..REPS / 1000 {
                let t = Instant::now();
                for _ in 0..1000 {
                    env.put_blocking(gptr, &buf).unwrap();
                }
                s_put.push(t.elapsed().as_nanos() as f64 / 1000.0);
            }
            // blocking get
            let mut s_get = Samples::new();
            for _ in 0..REPS / 1000 {
                let t = Instant::now();
                for _ in 0..1000 {
                    env.get_blocking(gptr, &mut dst).unwrap();
                }
                s_get.push(t.elapsed().as_nanos() as f64 / 1000.0);
            }
            // non-blocking put initiation (+ drain outside timing)
            let mut s_nb = Samples::new();
            for _ in 0..REPS / 1000 {
                let mut handles = Vec::with_capacity(1000);
                let t = Instant::now();
                for _ in 0..1000 {
                    handles.push(env.put(gptr, &buf).unwrap());
                }
                s_nb.push(t.elapsed().as_nanos() as f64 / 1000.0);
                env.waitall(handles).unwrap();
            }
            *out.lock().unwrap() = Shot {
                put_blocking_ns: s_put.median(),
                get_blocking_ns: s_get.median(),
                put_dtit_ns: s_nb.median(),
                puts: env.metrics.puts.get(),
                gets: env.metrics.gets.get(),
                puts_blocking: env.metrics.puts_blocking.get(),
                gets_blocking: env.metrics.gets_blocking.get(),
                cache_hits: env.metrics.cache_hits.get(),
                cache_misses: env.metrics.cache_misses.get(),
            };
        }
        env.barrier(DART_TEAM_ALL).unwrap();
    })
    .unwrap();
    out.into_inner().unwrap()
}

fn mpi_side() -> Shot {
    let out = Mutex::new(Shot::default());
    World::run(WorldConfig::local(2), |mpi| {
        let c = mpi.comm_world();
        let win = Win::allocate(&c, 4096).unwrap();
        win.lock_all().unwrap();
        c.barrier().unwrap();
        if c.rank() == 0 {
            let buf = [42u8; 8];
            let mut dst = [0u8; 8];
            let mut s_put = Samples::new();
            for _ in 0..REPS / 1000 {
                let t = Instant::now();
                for _ in 0..1000 {
                    win.put(&buf, 1, 0).unwrap();
                    win.flush(1).unwrap();
                }
                s_put.push(t.elapsed().as_nanos() as f64 / 1000.0);
            }
            let mut s_get = Samples::new();
            for _ in 0..REPS / 1000 {
                let t = Instant::now();
                for _ in 0..1000 {
                    win.get(&mut dst, 1, 0).unwrap();
                    win.flush(1).unwrap();
                }
                s_get.push(t.elapsed().as_nanos() as f64 / 1000.0);
            }
            let mut s_nb = Samples::new();
            for _ in 0..REPS / 1000 {
                let mut reqs = Vec::with_capacity(1000);
                let t = Instant::now();
                for _ in 0..1000 {
                    reqs.push(win.rput(&buf, 1, 0).unwrap());
                }
                s_nb.push(t.elapsed().as_nanos() as f64 / 1000.0);
                RmaRequest::waitall(reqs);
            }
            let mut o = out.lock().unwrap();
            o.put_blocking_ns = s_put.median();
            o.get_blocking_ns = s_get.median();
            o.put_dtit_ns = s_nb.median();
            o.puts = REPS as u64;
            o.puts_blocking = REPS as u64;
            o.gets_blocking = REPS as u64;
        }
        c.barrier().unwrap();
        win.unlock_all().unwrap();
    });
    out.into_inner().unwrap()
}

fn json_shot(s: &Shot) -> String {
    format!(
        "{{\"put_blocking_ns\":{:.1},\"get_blocking_ns\":{:.1},\"put_dtit_ns\":{:.1},\
         \"requests\":{{\"puts\":{},\"gets\":{},\"puts_blocking\":{},\"gets_blocking\":{}}},\
         \"segment_cache\":{{\"hits\":{},\"misses\":{}}}}}",
        s.put_blocking_ns,
        s.get_blocking_ns,
        s.put_dtit_ns,
        s.puts,
        s.gets,
        s.puts_blocking,
        s.gets_blocking,
        s.cache_hits,
        s.cache_misses
    )
}

fn main() {
    println!("==== §Perf — DART one-sided hot path (8-byte ops, zero-cost network) ====");
    let mpi = mpi_side();
    let coll = dart_side(true, true);
    let coll_nocache = dart_side(true, false);
    let nc = dart_side(false, true);
    let row = |name: &str, s: &Shot| {
        println!(
            "{:>30} {:>12} {:>12} {:>12}",
            name,
            fmt_ns(s.put_blocking_ns),
            fmt_ns(s.get_blocking_ns),
            fmt_ns(s.put_dtit_ns)
        );
    };
    println!("\n{:>30} {:>12} {:>12} {:>12}", "", "put_blocking", "get_blocking", "put (DTIT)");
    row("raw mpisim", &mpi);
    row("DART coll gptr (cached)", &coll);
    row("DART coll gptr (cache off)", &coll_nocache);
    row("DART non-collective gptr", &nc);
    println!(
        "\nDART-layer overhead vs raw MPI: cached {:+.0}/{:+.0}/{:+.0} ns, \
         cache-off {:+.0}/{:+.0}/{:+.0} ns, non-collective {:+.0}/{:+.0}/{:+.0} ns",
        coll.put_blocking_ns - mpi.put_blocking_ns,
        coll.get_blocking_ns - mpi.get_blocking_ns,
        coll.put_dtit_ns - mpi.put_dtit_ns,
        coll_nocache.put_blocking_ns - mpi.put_blocking_ns,
        coll_nocache.get_blocking_ns - mpi.get_blocking_ns,
        coll_nocache.put_dtit_ns - mpi.put_dtit_ns,
        nc.put_blocking_ns - mpi.put_blocking_ns,
        nc.get_blocking_ns - mpi.get_blocking_ns,
        nc.put_dtit_ns - mpi.put_dtit_ns,
    );
    println!(
        "segment cache: {} hits / {} misses over the collective run",
        coll.cache_hits, coll.cache_misses
    );
    println!("(paper: ~0 ns blocking, 80–130 ns non-blocking on 2.3 GHz Interlagos)");

    let json = format!(
        "{{\"bench\":\"perf_hotpath\",\"reps\":{REPS},\"unit\":\"ns_per_op\",\"results\":{{\
         \"mpi_raw\":{},\"dart_collective_cached\":{},\"dart_collective_nocache\":{},\
         \"dart_non_collective\":{}}}}}",
        json_shot(&mpi),
        json_shot(&coll),
        json_shot(&coll_nocache),
        json_shot(&nc)
    );
    std::fs::write("BENCH_hotpath.json", format!("{json}\n")).expect("write BENCH_hotpath.json");
    println!("\nwrote BENCH_hotpath.json");
}
