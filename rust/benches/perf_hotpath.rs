//! §Perf — the DART one-sided hot path, software cost only.
//!
//! Measures the per-op cost of the full dereference chain (flags dispatch,
//! teamlist lookup, unit translation, translation-table lookup, epoch
//! check, bounds check) with the network cost model DISABLED, against the
//! raw mpisim window ops. The delta is the pure DART-layer software
//! overhead — the quantity the whole §V evaluation is about.

use dart::bench_util::{fmt_ns, Samples};
use dart::dart::{run, DartConfig, DART_TEAM_ALL};
use dart::mpisim::{RmaRequest, Win, World, WorldConfig};
use dart::simnet::CostModel;
use std::sync::Mutex;
use std::time::Instant;

const REPS: usize = 20_000;

fn dart_side(collective: bool) -> (f64, f64, f64) {
    let out = Mutex::new((0f64, 0f64, 0f64));
    let cfg = DartConfig::with_units(2).with_cost(CostModel::zero()).with_pools(1 << 16, 1 << 16);
    run(cfg, |env| {
        let gptr = if collective {
            env.team_memalloc_aligned(DART_TEAM_ALL, 4096).unwrap().with_unit(1)
        } else {
            // exchange a non-collective pointer from unit 1
            let mine = env.memalloc(4096).unwrap();
            let mut all = vec![0u8; 32];
            env.allgather(DART_TEAM_ALL, &mine.to_bits().to_ne_bytes(), &mut all).unwrap();
            dart::dart::GlobalPtr::from_bits(u128::from_ne_bytes(all[16..32].try_into().unwrap()))
        };
        env.barrier(DART_TEAM_ALL).unwrap();
        if env.myid() == 0 {
            let buf = [42u8; 8];
            let mut dst = [0u8; 8];
            // blocking put
            let mut s_put = Samples::new();
            for _ in 0..REPS / 1000 {
                let t = Instant::now();
                for _ in 0..1000 {
                    env.put_blocking(gptr, &buf).unwrap();
                }
                s_put.push(t.elapsed().as_nanos() as f64 / 1000.0);
            }
            // blocking get
            let mut s_get = Samples::new();
            for _ in 0..REPS / 1000 {
                let t = Instant::now();
                for _ in 0..1000 {
                    env.get_blocking(gptr, &mut dst).unwrap();
                }
                s_get.push(t.elapsed().as_nanos() as f64 / 1000.0);
            }
            // non-blocking put initiation (+ drain outside timing)
            let mut s_nb = Samples::new();
            for _ in 0..REPS / 1000 {
                let mut handles = Vec::with_capacity(1000);
                let t = Instant::now();
                for _ in 0..1000 {
                    handles.push(env.put(gptr, &buf).unwrap());
                }
                s_nb.push(t.elapsed().as_nanos() as f64 / 1000.0);
                env.waitall(handles).unwrap();
            }
            *out.lock().unwrap() = (s_put.median(), s_get.median(), s_nb.median());
        }
        env.barrier(DART_TEAM_ALL).unwrap();
    })
    .unwrap();
    out.into_inner().unwrap()
}

fn mpi_side() -> (f64, f64, f64) {
    let out = Mutex::new((0f64, 0f64, 0f64));
    World::run(WorldConfig::local(2), |mpi| {
        let c = mpi.comm_world();
        let win = Win::allocate(&c, 4096).unwrap();
        win.lock_all().unwrap();
        c.barrier().unwrap();
        if c.rank() == 0 {
            let buf = [42u8; 8];
            let mut dst = [0u8; 8];
            let mut s_put = Samples::new();
            for _ in 0..REPS / 1000 {
                let t = Instant::now();
                for _ in 0..1000 {
                    win.put(&buf, 1, 0).unwrap();
                    win.flush(1).unwrap();
                }
                s_put.push(t.elapsed().as_nanos() as f64 / 1000.0);
            }
            let mut s_get = Samples::new();
            for _ in 0..REPS / 1000 {
                let t = Instant::now();
                for _ in 0..1000 {
                    win.get(&mut dst, 1, 0).unwrap();
                    win.flush(1).unwrap();
                }
                s_get.push(t.elapsed().as_nanos() as f64 / 1000.0);
            }
            let mut s_nb = Samples::new();
            for _ in 0..REPS / 1000 {
                let mut reqs = Vec::with_capacity(1000);
                let t = Instant::now();
                for _ in 0..1000 {
                    reqs.push(win.rput(&buf, 1, 0).unwrap());
                }
                s_nb.push(t.elapsed().as_nanos() as f64 / 1000.0);
                RmaRequest::waitall(reqs);
            }
            *out.lock().unwrap() = (s_put.median(), s_get.median(), s_nb.median());
        }
        c.barrier().unwrap();
        win.unlock_all().unwrap();
    });
    out.into_inner().unwrap()
}

fn main() {
    println!("==== §Perf — DART one-sided hot path (8-byte ops, zero-cost network) ====");
    let (mp, mg, mn) = mpi_side();
    let (cp, cg, cn) = dart_side(true);
    let (np, ng, nn) = dart_side(false);
    println!("\n{:>28} {:>12} {:>12} {:>12}", "", "put_blocking", "get_blocking", "put (DTIT)");
    println!("{:>28} {:>12} {:>12} {:>12}", "raw mpisim", fmt_ns(mp), fmt_ns(mg), fmt_ns(mn));
    println!(
        "{:>28} {:>12} {:>12} {:>12}",
        "DART (collective gptr)",
        fmt_ns(cp),
        fmt_ns(cg),
        fmt_ns(cn)
    );
    println!(
        "{:>28} {:>12} {:>12} {:>12}",
        "DART (non-collective gptr)",
        fmt_ns(np),
        fmt_ns(ng),
        fmt_ns(nn)
    );
    println!(
        "\nDART-layer overhead: collective {:+.0}/{:+.0}/{:+.0} ns, non-collective {:+.0}/{:+.0}/{:+.0} ns",
        cp - mp,
        cg - mg,
        cn - mn,
        np - mp,
        ng - mg,
        nn - mn
    );
    println!("(paper: ~0 ns blocking, 80–130 ns non-blocking on 2.3 GHz Interlagos)");
}
