//! Ablation A4 — the paper's §VI future work: MPI-3 **shared-memory
//! windows** under DART ("true zero-copy mechanisms, as opposed to
//! traditional single-copy mechanisms. An early implementation ... shows
//! promising preliminary results: especially for small message sizes,
//! intra- and inter-NUMA communication becomes a lot more efficient").
//!
//! Measured placements (2 units on a 2-node Hermit model; the labelled
//! series name the *pair's* relationship, which is what the zero-copy
//! criterion — same node — keys on):
//!
//! - **intra-NUMA** (`Block`): both units on node 0, NUMA domain 0;
//! - **inter-NUMA** (`ScatterNuma`): node 0, *adjacent* NUMA domains 0/1;
//! - **inter-NUMA far** (`Custom`): node 0, NUMA domains 0 and 3 — the
//!   maximal within-node distance on the 4-domain Interlagos node, so the
//!   NUMA-distinguishing case is measured explicitly rather than inferred
//!   from the adjacent pair;
//! - **inter-node** (`ScatterNode`): distinct nodes.
//!
//! Expected shape: large wins for *all three* same-node placements (the
//! quoted "intra- and inter-NUMA" claim — the zero-copy path does not
//! distinguish NUMA distance, so the two inter-NUMA series should win by
//! similar factors), and *no change* inter-node.

use dart::bench_util::{paper_placements, print_comparison_table, quick_msg_sizes, Samples};
use dart::dart::{run, DartConfig, DART_TEAM_ALL};
use dart::simnet::{CoreCoord, PinPolicy, Tier};
use std::sync::Mutex;
use std::time::Instant;

fn measure(pin: PinPolicy, shmem: bool, sizes: &[usize]) -> Vec<(usize, f64)> {
    let rows = Mutex::new(Vec::new());
    let cfg = DartConfig::hermit(2, 2).with_pin(pin).with_shmem_windows(shmem);
    run(cfg, |env| {
        let g = env.team_memalloc_aligned(DART_TEAM_ALL, 1 << 21).unwrap();
        for &size in sizes {
            let buf = vec![0xC3u8; size];
            env.barrier(DART_TEAM_ALL).unwrap();
            if env.myid() == 0 {
                let reps = dart::bench_util::adaptive_reps(size, 256);
                let mut s = Samples::new();
                for _ in 0..reps {
                    let t = Instant::now();
                    env.put_blocking(g.with_unit(1), &buf).unwrap();
                    s.push(t.elapsed().as_nanos() as f64);
                }
                rows.lock().unwrap().push((size, s.median()));
            }
            env.barrier(DART_TEAM_ALL).unwrap();
        }
        env.team_memfree(DART_TEAM_ALL, g).unwrap();
    })
    .unwrap();
    rows.into_inner().unwrap()
}

fn main() {
    println!("==== Ablation A4 — §VI shared-memory windows (zero-copy) ====");
    println!("(blocking put DTCT; columns: regular windows vs shared-memory windows)");
    let sizes = quick_msg_sizes();
    // The three paper placements, plus the NUMA-distinguishing one: both
    // units on node 0 but on *maximally distant* NUMA domains (0 and 3).
    let far_numa = PinPolicy::Custom(vec![
        CoreCoord { node: 0, numa: 0, core: 0 },
        CoreCoord { node: 0, numa: 3, core: 0 },
    ]);
    let mut placements: Vec<(String, PinPolicy)> = paper_placements()
        .into_iter()
        .map(|(tier, pin)| (tier.label().to_string(), pin))
        .collect();
    placements.insert(2, (format!("{} far (domains 0/3)", Tier::InterNuma.label()), far_numa));
    for (label, pin) in placements {
        let regular = measure(pin.clone(), false, &sizes);
        let shmem = measure(pin, true, &sizes);
        let rows: Vec<(usize, f64, f64)> = shmem
            .iter()
            .zip(&regular)
            .map(|(&(s, sh), &(_, rg))| (s, sh, rg))
            .collect();
        print_comparison_table(&format!("A4 — {label}"), "ns", ("shmem", "regular"), &rows);
        let speedup_small: f64 = rows
            .iter()
            .filter(|&&(s, _, _)| s <= 4096)
            .map(|&(_, sh, rg)| rg / sh)
            .product::<f64>()
            .powf(1.0 / rows.iter().filter(|&&(s, _, _)| s <= 4096).count().max(1) as f64);
        println!("geomean small-message (≤4 KiB) speedup: {speedup_small:.2}×  [{label}]");
    }
    println!(
        "\nExpected: big speedups on every same-node placement (intra-NUMA and both \
         inter-NUMA distances), ≈1.0× inter-node (§VI)."
    );
}
