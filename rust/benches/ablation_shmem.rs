//! Ablation A4 — the paper's §VI future work: MPI-3 **shared-memory
//! windows** under DART ("true zero-copy mechanisms, as opposed to
//! traditional single-copy mechanisms. An early implementation ... shows
//! promising preliminary results: especially for small message sizes,
//! intra- and inter-NUMA communication becomes a lot more efficient").
//!
//! Expected shape: large wins intra-node (both placements), *no change*
//! inter-node — exactly what the quoted sentence claims.

use dart::bench_util::{paper_placements, print_comparison_table, quick_msg_sizes, Samples};
use dart::dart::{run, DartConfig, DART_TEAM_ALL};
use dart::simnet::PinPolicy;
use std::sync::Mutex;
use std::time::Instant;

fn measure(pin: PinPolicy, shmem: bool, sizes: &[usize]) -> Vec<(usize, f64)> {
    let rows = Mutex::new(Vec::new());
    let cfg = DartConfig::hermit(2, 2).with_pin(pin).with_shmem_windows(shmem);
    run(cfg, |env| {
        let g = env.team_memalloc_aligned(DART_TEAM_ALL, 1 << 21).unwrap();
        for &size in sizes {
            let buf = vec![0xC3u8; size];
            env.barrier(DART_TEAM_ALL).unwrap();
            if env.myid() == 0 {
                let reps = dart::bench_util::adaptive_reps(size, 256);
                let mut s = Samples::new();
                for _ in 0..reps {
                    let t = Instant::now();
                    env.put_blocking(g.with_unit(1), &buf).unwrap();
                    s.push(t.elapsed().as_nanos() as f64);
                }
                rows.lock().unwrap().push((size, s.median()));
            }
            env.barrier(DART_TEAM_ALL).unwrap();
        }
        env.team_memfree(DART_TEAM_ALL, g).unwrap();
    })
    .unwrap();
    rows.into_inner().unwrap()
}

fn main() {
    println!("==== Ablation A4 — §VI shared-memory windows (zero-copy) ====");
    println!("(blocking put DTCT; columns: regular windows vs shared-memory windows)");
    let sizes = quick_msg_sizes();
    for (tier, pin) in paper_placements() {
        let regular = measure(pin.clone(), false, &sizes);
        let shmem = measure(pin, true, &sizes);
        let rows: Vec<(usize, f64, f64)> = shmem
            .iter()
            .zip(&regular)
            .map(|(&(s, sh), &(_, rg))| (s, sh, rg))
            .collect();
        print_comparison_table(&format!("A4 — {tier}"), "ns", ("shmem", "regular"), &rows);
        let speedup_small: f64 = rows
            .iter()
            .filter(|&&(s, _, _)| s <= 4096)
            .map(|&(_, sh, rg)| rg / sh)
            .product::<f64>()
            .powf(1.0 / rows.iter().filter(|&&(s, _, _)| s <= 4096).count().max(1) as f64);
        println!("geomean small-message (≤4 KiB) speedup: {speedup_small:.2}×  [{tier}]");
    }
    println!("\nExpected: big speedups intra-NUMA / inter-NUMA, ≈1.0× inter-node (§VI).");
}
