//! Distributed SUMMA matmul over the DART PGAS + AOT GEMM artifacts.
//!
//! ```sh
//! cargo run --release --example matmul [units]
//! ```
//!
//! With `P` units the problem is `(64P × 64P) @ (64P × 64)`: B's K-panels
//! live in collective global memory and are fetched one-sidedly (the owner
//! never participates — pure PGAS), the per-panel `C += A_p @ B_p` runs as
//! the `summa_f32_64x64x64` Pallas artifact. Verified against a
//! single-threaded reference.

use dart::apps::matmul::{reference, run_distributed, SummaConfig};
use dart::dart::{run, DartConfig};
use dart::runtime::Engine;
use std::sync::Mutex;
use std::time::Instant;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let units: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let cfg = SummaConfig::block64();
    let (m, k, n) = (cfg.mb * units, cfg.kb * units, cfg.nb);
    println!("== distributed SUMMA: C({m}×{n}) = A({m}×{k}) @ B({k}×{n}) on {units} units ==");

    let blocks = Mutex::new(vec![Vec::new(); units]);
    let norm = Mutex::new(0f64);
    let wall = Instant::now();
    run(DartConfig::hermit(units, (units + 31) / 32), |env| {
        let engine = Engine::new().expect("PJRT engine");
        let r = run_distributed(env, &engine, &cfg).expect("summa run");
        blocks.lock().unwrap()[env.team_myid(cfg.team).unwrap()] = r.c_local.clone();
        if env.myid() == 0 {
            *norm.lock().unwrap() = r.global_norm;
        }
    })?;
    let elapsed = wall.elapsed();

    // Assemble and verify.
    let c_dist: Vec<f32> = blocks.into_inner().unwrap().concat();
    let c_ref = reference(units, cfg.mb, cfg.kb, cfg.nb);
    let mut max_err = 0f32;
    for (d, r) in c_dist.iter().zip(&c_ref) {
        max_err = max_err.max((d - r).abs());
    }
    println!("global ||C||_F = {:.6}", norm.into_inner().unwrap());
    println!("max |C_dist − C_ref| = {max_err:.3e}");
    assert!(max_err < 1e-3, "verification failed");

    let flops = 2.0 * m as f64 * k as f64 * n as f64;
    println!(
        "{:.2} MFLOP in {:.2?} → {:.2} GFLOP/s — matmul e2e OK",
        flops / 1e6,
        elapsed,
        flops / elapsed.as_secs_f64() / 1e9
    );
    Ok(())
}
