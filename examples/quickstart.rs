//! Quickstart: a tour of the DART PGAS API.
//!
//! ```sh
//! cargo run --release --example quickstart [units]
//! ```
//!
//! Demonstrates, on one SPMD launch: identity queries, sorted groups,
//! sub-team creation, collective aligned allocation + global-pointer
//! arithmetic, one-sided blocking/non-blocking put/get, collectives, and
//! the MCS lock.

use dart::dart::{run, DartConfig, DartGroup, DART_TEAM_ALL};
use dart::mpisim::MpiOp;
use std::sync::Mutex;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let units: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    println!("== DART quickstart: {units} units ==");
    let log = Mutex::new(Vec::<String>::new());

    run(DartConfig::with_units(units), |env| {
        let me = env.myid();

        // --- 1. Global memory + one-sided communication -----------------
        // A symmetric allocation: every unit owns `units` u64 slots.
        let table = env
            .team_memalloc_aligned(DART_TEAM_ALL, (units * 8) as u64)
            .expect("alloc");
        // Everyone deposits its id into slot `me` of EVERY unit — pure
        // global-pointer arithmetic, no receives anywhere.
        let mut handles = Vec::new();
        for u in 0..units {
            let dst = table.with_unit(u as i32).add((me as u64) * 8);
            handles.push(env.put(dst, &(me as u64 + 100).to_ne_bytes()).expect("put"));
        }
        env.waitall(handles).expect("waitall");
        env.barrier(DART_TEAM_ALL).expect("barrier");
        // Read my local slots back.
        let mut slots = vec![0u64; units];
        env.local_read(table.with_unit(me), dart::mpisim::as_bytes_mut(&mut slots))
            .expect("local_read");
        assert!(slots.iter().enumerate().all(|(u, &v)| v == u as u64 + 100));

        // --- 2. Collectives ---------------------------------------------
        let mut sum = [0i64];
        env.allreduce(DART_TEAM_ALL, &[me as i64], &mut sum, MpiOp::Sum).expect("allreduce");

        // --- 3. Teams over sorted groups --------------------------------
        // The evens team, built by adding members in scrambled order.
        let w = env.mpi_world_group();
        let mut evens = DartGroup::new();
        for u in (0..units as i32).filter(|u| u % 2 == 0).rev() {
            evens.addmember(u, &w).expect("addmember");
        }
        let team = env.team_create(DART_TEAM_ALL, &evens).expect("team_create");
        if let Some(t) = team {
            let tr = env.team_myid(t).expect("team_myid");
            let g = env.team_memalloc_aligned(t, 64).expect("team alloc");
            env.put_blocking(g.with_unit(me), &[tr as u8; 8]).expect("put");
            env.barrier(t).expect("team barrier");
            env.team_memfree(t, g).expect("team free");
            env.team_destroy(t).expect("team destroy");
        }

        // --- 4. The MCS lock ---------------------------------------------
        let lock = env.lock_init(DART_TEAM_ALL).expect("lock_init");
        env.lock_acquire(&lock).expect("acquire");
        log.lock().unwrap().push(format!(
            "unit {me}: in critical section (sum of ids = {})",
            sum[0]
        ));
        env.lock_release(&lock).expect("release");
        env.barrier(DART_TEAM_ALL).expect("barrier");
        env.lock_free(lock).expect("lock_free");
        env.team_memfree(DART_TEAM_ALL, table).expect("free");
    })?;

    for line in log.into_inner().unwrap() {
        println!("{line}");
    }
    println!("quickstart OK");
    Ok(())
}
