//! Producer/consumer over the PGAS — the paper's own motivating picture
//! (§I: "a producer can write data into shared memory, while a consumer
//! accesses the data with a read operation in much the same way as ... a
//! sequential program, however the programmer needs to use certain
//! synchronization mechanism, such as lock").
//!
//! ```sh
//! cargo run --release --example prodcons [units] [items-per-producer]
//! ```
//!
//! A bounded ring buffer lives in unit 0's partition of a collective
//! allocation; `units − 1` producers push tagged items under the DART MCS
//! lock; unit 0 consumes. Every access is a one-sided put/get on global
//! pointers — no message passing in the application code.
//!
//! **This is the repo's canonical overlap example.** The consumer does
//! *not* busy-wait on the tail with repeated blocking gets (the original
//! formulation — one full network round-trip per poll, all latency-bound).
//! Instead it keeps exactly one *nonblocking* get of the tail in flight
//! (`dart_get` → handle) and overlaps useful work with it: while the
//! probe flies, it drains the items it already knows about with blocking
//! slot gets, publishes the new head, and only then completes the probe
//! with the `test` API (`DartEnv::test` — nonblocking, returns the handle
//! back while in flight). Between tests it yields a cooperative
//! `progress_poll` tick to the asynchronous progress engine (the launch
//! uses `ProgressMode::Polling`), so deferred work retires in the gaps.

use dart::dart::{run, DartConfig, ProgressMode, DART_TEAM_ALL};
use std::sync::atomic::{AtomicU64, Ordering};

const CAP: u64 = 16; // ring capacity (slots)

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let units: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let per_prod: u64 = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(100);
    assert!(units >= 2, "need at least one producer and the consumer");
    let n_items = (units as u64 - 1) * per_prod;
    println!("== PGAS producer/consumer: {} producers × {per_prod} items, ring of {CAP} ==", units - 1);

    let consumed_sum = AtomicU64::new(0);
    let produced_sum = AtomicU64::new(0);

    run(DartConfig::with_units(units).with_progress_mode(ProgressMode::Polling), |env| {
        // Layout in unit 0's segment: [head, tail, slot0..slot15] as u64.
        let ring = env.team_memalloc_aligned(DART_TEAM_ALL, (2 + CAP) * 8).unwrap();
        let r0 = ring.with_unit(0);
        let head = r0; // consumer cursor
        let tail = r0.add(8); // producer cursor
        let slot = |i: u64| r0.add((2 + i % CAP) * 8);
        let lock = env.lock_init(DART_TEAM_ALL).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();

        let read_u64 = |g| {
            let mut b = [0u8; 8];
            env.get_blocking(g, &mut b).unwrap();
            u64::from_ne_bytes(b)
        };

        if env.myid() == 0 {
            // Consumer: drain n_items with ONE nonblocking tail probe in
            // flight at a time, overlapped with draining known items.
            let mut sum = 0u64;
            let mut h = 0u64; // my head cursor
            let mut published = 0u64; // head value producers can see
            let mut known_tail = 0u64; // last observed tail
            let mut tbuf = [0u8; 8];
            let mut probe = env.get(tail, &mut tbuf).unwrap();
            while h < n_items {
                // Overlap: consume everything already known while the
                // probe is in flight.
                while h < known_tail {
                    sum = sum.wrapping_add(read_u64(slot(h)));
                    h += 1;
                }
                if h > published {
                    // Publish the advanced head so producers reuse slots
                    // (only when it moved — no blocking put per poll).
                    env.put_blocking(head, &h.to_ne_bytes()).unwrap();
                    published = h;
                }
                // Complete (or keep flying) the probe via the test API.
                match env.test(probe) {
                    Ok(()) => {
                        known_tail = u64::from_ne_bytes(tbuf);
                        probe = env.get(tail, &mut tbuf).unwrap();
                    }
                    Err(inflight) => {
                        probe = inflight;
                        env.progress_poll();
                        std::thread::yield_now();
                    }
                }
            }
            env.wait(probe).unwrap();
            consumed_sum.store(sum, Ordering::SeqCst);
        } else {
            // Producer: push `per_prod` tagged items under the lock.
            let me = env.myid() as u64;
            for k in 0..per_prod {
                let item = me * 1_000_000 + k;
                produced_sum.fetch_add(item, Ordering::SeqCst);
                loop {
                    env.lock_acquire(&lock).unwrap();
                    let t = read_u64(tail);
                    let hd = read_u64(head);
                    if t - hd < CAP {
                        // room: write the item, then advance the tail
                        env.put_blocking(slot(t), &item.to_ne_bytes()).unwrap();
                        env.put_blocking(tail, &(t + 1).to_ne_bytes()).unwrap();
                        env.lock_release(&lock).unwrap();
                        break;
                    }
                    // full: back off
                    env.lock_release(&lock).unwrap();
                    std::thread::yield_now();
                }
            }
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        env.lock_free(lock).unwrap();
        env.team_memfree(DART_TEAM_ALL, ring).unwrap();
    })?;

    let produced = produced_sum.load(Ordering::SeqCst);
    let consumed = consumed_sum.load(Ordering::SeqCst);
    println!("produced sum = {produced}, consumed sum = {consumed}");
    assert_eq!(produced, consumed, "every item consumed exactly once");
    println!("prodcons OK ({n_items} items through a {CAP}-slot PGAS ring)");
    Ok(())
}
