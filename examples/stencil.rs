//! END-TO-END DRIVER: distributed 2D heat diffusion across all three
//! layers (recorded in EXPERIMENTS.md).
//!
//! ```sh
//! cargo run --release --example stencil [units] [steps]
//! ```
//!
//! Every unit owns a 64×64 block of a (units·64)×64 grid held in DART
//! collective global memory; per step it halo-exchanges with one-sided
//! `dart_get`s, runs the AOT JAX/Pallas stencil artifact on its PJRT
//! engine, and all units reduce the residual. The run is verified against
//! a single-threaded reference and the residual curve is printed.

use dart::apps::stencil::{run_distributed, run_reference, StencilConfig};
use dart::dart::{run, DartConfig};
use dart::runtime::Engine;
use std::sync::Mutex;
use std::time::Instant;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let units: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(8);
    let steps: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(200);
    let cfg = StencilConfig::block64(steps);
    println!(
        "== distributed stencil: {} units × {}×{} blocks, {} steps, artifact {} ==",
        units, cfg.local_rows, cfg.width, steps, cfg.artifact
    );

    let report = Mutex::new(None);
    let wall = Instant::now();
    run(DartConfig::hermit(units, (units + 31) / 32), |env| {
        let engine = Engine::new().expect("PJRT engine");
        let r = run_distributed(env, &engine, &cfg).expect("stencil run");
        if env.myid() == 0 {
            *report.lock().unwrap() = Some(r);
        }
    })?;
    let elapsed = wall.elapsed();
    let report = report.into_inner().unwrap().unwrap();

    // Residual curve (the "loss curve" of this workload).
    println!("\nstep        residual");
    let n = report.residuals.len();
    for (i, r) in report.residuals.iter().enumerate() {
        if i < 10 || i % (n / 10).max(1) == 0 || i == n - 1 {
            println!("{i:>4}  {r:>14.6}");
        }
    }
    assert!(
        report.residuals.windows(2).all(|w| w[1] <= w[0] * 1.5),
        "diffusion must not diverge"
    );

    // Verify against the single-threaded reference.
    let (ref_grid, ref_res) = run_reference(units * cfg.local_rows, cfg.width, steps, 0.25);
    let ref_checksum: f64 = ref_grid.iter().map(|&v| v as f64).sum();
    let rel = (report.global_checksum - ref_checksum).abs() / ref_checksum.abs().max(1e-9);
    println!("\nchecksum: distributed={:.6} reference={:.6} (rel err {:.2e})", report.global_checksum, ref_checksum, rel);
    let res_rel = (report.residuals[n - 1] - ref_res[n - 1]).abs() / ref_res[n - 1].max(1e-12);
    println!("final residual: distributed={:.6e} reference={:.6e} (rel err {:.2e})", report.residuals[n - 1], ref_res[n - 1], res_rel);
    assert!(rel < 1e-5, "checksum mismatch vs reference");
    assert!(res_rel < 1e-3, "residual mismatch vs reference");

    let cells = (units * cfg.local_rows * cfg.width * steps) as f64;
    println!(
        "\n{} cell-updates in {:.2?} → {:.1} Mcell/s  — stencil e2e OK",
        cells as u64,
        elapsed,
        cells / elapsed.as_secs_f64() / 1e6
    );
    Ok(())
}
