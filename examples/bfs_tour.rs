//! BFS tour: a seeded R-MAT graph in distributed CSR form, remote
//! adjacency pulls, CAS-claimed parents, and oracle-checked levels.
//!
//! ```sh
//! cargo run --release --example bfs_tour
//! ```
//!
//! The launch models 8 units round-robin over a 2-node Hermit cluster
//! with shmem windows on — the placement where the claim protocol's
//! locality options matter. The tour walks the irregular stack:
//!
//! 1. **`dash::Graph`** — every unit replays the same seeded Kronecker
//!    edge stream and keeps its owned rows, so the distributed CSR comes
//!    up with zero communication beyond one capacity allreduce.
//! 2. **Remote adjacency pull** — `get_neighbors` on a non-owned vertex:
//!    two scalar gets plus ONE coalesced vector-typed get.
//! 3. **Level-synchronous BFS** — `apps::bfs` races one
//!    `compare_and_swap` per candidate claim at the distributed parent
//!    array; levels are race-independent even though parents are not.
//! 4. **Intra-node combining** — the same traversal with `combine` on
//!    dedups candidates node-locally first; the level summary is
//!    bit-identical, the claim count is not.
//! 5. **The oracle** — `run_checked` verifies levels, parent edges, and
//!    monotonicity against the sequential replay.

use dart::apps::bfs::{reference_summary, run_checked, run_distributed, BfsConfig};
use dart::dart::{run, DartConfig, DART_TEAM_ALL};
use dart::dash::{Graph, GraphConfig};
use dart::simnet::PinPolicy;
use std::sync::Mutex;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let cfg = DartConfig::hermit(8, 2)
        .with_pin(PinPolicy::ScatterNode)
        .with_pools(1 << 18, 1 << 21)
        .with_shmem_windows(true);
    let graph = GraphConfig { scale: 7, edge_factor: 8, seed: 0xB0F5_7011 };
    println!("== BFS tour: R-MAT scale {} over 8 units on 2 Hermit nodes ==", graph.scale);
    let log = Mutex::new(Vec::<(usize, String)>::new());

    run(cfg, |env| {
        // --- 1. The distributed CSR comes up collectively. -------------
        let g = Graph::build(env, DART_TEAM_ALL, graph).expect("graph build");
        let me = env.team_myid(DART_TEAM_ALL).expect("rank");
        let rows = g.my_rows();

        // --- 2. Pull a remote row's neighbors (owner-partitioned, so
        // any vertex outside my rows costs one coalesced vector get). --
        let remote_v = (rows.end) % g.nverts();
        let pulled = g.get_neighbors(remote_v).expect("remote pull");
        log.lock().unwrap().push((
            me,
            format!(
                "unit {me}: rows {:?} ({} edges stored) | pulled v{remote_v} from unit {}: \
                 degree {}",
                rows,
                g.local_edge_count(),
                g.owner_of(remote_v),
                pulled.len()
            ),
        ));
        g.free().expect("graph free");

        // --- 3 + 4. Traverse twice: flat claims, then intra-node
        // combining. Levels must agree bit-for-bit; claims differ. ------
        let flat = BfsConfig { graph, root: 0, combine: false, team: DART_TEAM_ALL };
        let combined = BfsConfig { combine: true, ..flat.clone() };
        let a = run_distributed(env, &flat).expect("flat bfs");
        let b = run_distributed(env, &combined).expect("combined bfs");
        assert_eq!(a.summary, b.summary, "combining changed the levels");

        // --- 5. And once more against the sequential oracle. -----------
        let checked = run_checked(env, &flat).expect("oracle-checked bfs");
        if me == 0 {
            log.lock().unwrap().push((
                usize::MAX,
                format!(
                    "reached {}/{} vertices in {} levels | checksum {:#x} | \
                     claims: flat {} vs combined {}",
                    checked.summary.reached,
                    graph.nverts(),
                    checked.summary.max_level + 1,
                    checked.summary.checksum,
                    a.claim_attempts,
                    b.claim_attempts
                ),
            ));
        }
        env.barrier(DART_TEAM_ALL).expect("barrier");
    })?;

    let mut lines = log.into_inner().unwrap();
    lines.sort_by_key(|&(id, _)| id);
    for (_, line) in lines {
        println!("{line}");
    }
    let oracle = reference_summary(&BfsConfig {
        graph,
        root: 0,
        combine: false,
        team: DART_TEAM_ALL,
    });
    println!(
        "(sequential oracle agrees: reached {}, max level {}, checksum {:#x})",
        oracle.reached, oracle.max_level, oracle.checksum
    );
    Ok(())
}
