//! Locality tour: who shares my node, split-by-locality teams, leader
//! election, and a hierarchical allreduce.
//!
//! ```sh
//! cargo run --release --example locality_tour
//! ```
//!
//! The launch models 12 units round-robin over a 3-node Hermit cluster —
//! the placement where locality-blind communication hurts most (every
//! power-of-two rank distance crosses the interconnect). The tour walks
//! the locality API of the runtime:
//!
//! 1. **`unit_locality`** — any unit's `(node, numa, core)` coordinate,
//!    so an application can route per tier (the locality-awareness
//!    follow-up papers' core argument).
//! 2. **`team_split_locality`** — the `MPI_Comm_split_type` analogue:
//!    node-local teams plus a cross-node leader team, each an ordinary
//!    DART team (collectives, allocation, rank translation all work).
//! 3. **Leader election** — leadership falls out of the split: each
//!    node's lowest unit holds the leader-team id, everyone else gets
//!    `None`.
//! 4. **Hierarchical allreduce** — with
//!    `DartConfig::hierarchical_collectives` on, the same `allreduce`
//!    call decomposes into intra-node reduce → leader exchange →
//!    intra-node fan-out, observable through
//!    `Metrics::{hier_coll_intra_ops, hier_coll_inter_ops}`.

use dart::dart::{run, DartConfig, LocalityScope, DART_TEAM_ALL};
use dart::mpisim::MpiOp;
use dart::simnet::PinPolicy;
use std::sync::Mutex;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let cfg = DartConfig::hermit(12, 3)
        .with_pin(PinPolicy::ScatterNode)
        .with_pools(1 << 16, 1 << 20)
        .with_hierarchical_collectives(true);
    println!("== locality tour: 12 units round-robin over 3 Hermit nodes ==");
    let log = Mutex::new(Vec::<(i32, String)>::new());

    run(cfg, |env| {
        // --- 1. Where am I? Where is everyone else? --------------------
        let me = env.myid();
        let here = env.unit_locality(me).expect("my coordinate");
        let peer = (me + 1) % env.size() as i32;
        let shares = env.same_node(me, peer).expect("same_node");

        // --- 2 + 3. Split by node; leadership falls out of the split. --
        let split = env.team_split_locality(DART_TEAM_ALL, LocalityScope::Node).expect("split");
        let local_size = env.team_size(split.local).expect("local size");
        let role = match split.leaders {
            Some(lt) => format!(
                "LEADER of node {} (leader team: {} nodes)",
                here.node,
                env.team_size(lt).expect("leader size")
            ),
            None => format!("member of node {}", here.node),
        };

        // --- 4. One allreduce, two levels. -----------------------------
        // Counts are u64, so the hierarchical result is bit-identical to
        // the flat one — only the routing changes.
        let mut total = [0u64];
        env.allreduce(DART_TEAM_ALL, &[me as u64 + 1], &mut total, MpiOp::Sum).expect("allreduce");
        assert_eq!(total[0], (1..=12).sum::<u64>());

        log.lock().unwrap().push((
            me,
            format!(
                "unit {me:2} @ {here} | next peer on my node: {shares:5} | \
                 node team: {local_size} units | {role} | sum={} | \
                 phases: intra={} inter={}",
                total[0],
                env.metrics.hier_coll_intra_ops.get(),
                env.metrics.hier_coll_inter_ops.get()
            ),
        ));
        env.barrier(DART_TEAM_ALL).expect("barrier");
    })?;

    let mut lines = log.into_inner().unwrap();
    lines.sort_by_key(|&(id, _)| id);
    for (_, line) in lines {
        println!("{line}");
    }
    println!("(leaders crossed the interconnect once; everyone else stayed on-node)");
    Ok(())
}
