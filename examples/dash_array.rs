//! `dash` tour: typed distributed arrays, owner-computes algorithms and
//! pattern redistribution on top of the DART runtime.
//!
//! ```sh
//! cargo run --release --example dash_array [units]
//! ```

use dart::dart::{run, DartConfig, DART_TEAM_ALL};
use dart::dash::{algorithms, Array, Pattern};
use std::sync::Mutex;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let units: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let n = 1 << 10;
    println!("== dash tour: {units} units, {n} elements ==");
    let log = Mutex::new(Vec::<String>::new());

    run(DartConfig::with_units(units), |env| {
        // --- 1. A BLOCKED Array<f64>: fill, transform, reduce -----------
        let a: Array<'_, f64> = Array::blocked(env, DART_TEAM_ALL, n).expect("alloc");
        algorithms::fill(&a, 1.0).expect("fill");
        algorithms::transform(&a, |g, _| g as f64).expect("transform");
        let total = algorithms::sum(&a).expect("sum");
        assert_eq!(total, (n * (n - 1) / 2) as f64);
        let (max_at, max) = algorithms::max_element(&a).expect("max");
        assert_eq!((max_at, max), (n - 1, (n - 1) as f64));

        // --- 2. Redistribute BLOCKED → BLOCKCYCLIC(16) -------------------
        // Same elements, new layout; the pattern coalesces the traffic
        // into 16-element runs (watch Metrics::dash_coalesced_runs).
        let b: Array<'_, f64> =
            Array::block_cyclic(env, DART_TEAM_ALL, n, 16).expect("alloc");
        let ops = algorithms::copy(&a, &b).expect("copy");
        assert_eq!(algorithms::sum(&b).expect("sum"), total);

        // --- 3. Owner-computes local view: zero network ------------------
        let local_share: f64 = b.read_local().expect("local").iter().sum();

        log.lock().unwrap().push(format!(
            "unit {}: sum={total} max=({max_at},{max}) redist_ops={ops} local_share={local_share}",
            env.myid()
        ));
        b.free().expect("free");
        a.free().expect("free");
    })?;

    for line in log.into_inner().unwrap() {
        println!("{line}");
    }
    Ok(())
}
