//! Pingpong: a quick look at the paper's headline measurement — DART
//! one-sided operations vs raw MPI-3 RMA, across placements.
//!
//! ```sh
//! cargo run --release --example pingpong
//! ```
//!
//! This is the interactive sibling of the full figure benches
//! (`cargo bench`): one pair of units per placement tier, a short sweep of
//! message sizes, blocking put DTCT + non-blocking put DTIT for DART and
//! raw mpisim side by side.

use dart::bench_util::{fmt_ns, Samples};
use dart::dart::{run, DartConfig, DART_TEAM_ALL};
use dart::simnet::{PinPolicy, Tier};
use std::sync::Mutex;
use std::time::Instant;

const REPS: usize = 200;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    println!("== DART vs MPI pingpong (blocking put DTCT / non-blocking put DTIT) ==");
    for (tier, pin) in [
        (Tier::IntraNuma, PinPolicy::Block),
        (Tier::InterNuma, PinPolicy::ScatterNuma),
        (Tier::InterNode, PinPolicy::ScatterNode),
    ] {
        println!("\n-- placement: {tier} --");
        println!("{:>10} {:>14} {:>14} {:>14} {:>14}", "size", "DART put_b", "MPI put+flush", "DART put(nb)", "MPI rput");
        let rows = Mutex::new(Vec::new());
        let cfg = DartConfig::hermit(2, 2).with_pin(pin);
        run(cfg, |env| {
            let me = env.myid();
            let g = env.team_memalloc_aligned(DART_TEAM_ALL, 1 << 21).unwrap();
            let comm = env.placement(); // placement sanity
            let _ = comm;
            for pow in [0usize, 6, 10, 12, 14, 17, 21] {
                let size = 1usize << pow;
                let buf = vec![0xA5u8; size];
                env.barrier(DART_TEAM_ALL).unwrap();
                if me == 0 {
                    // DART blocking put DTCT
                    let mut s_dart_b = Samples::new();
                    for _ in 0..REPS {
                        let t = Instant::now();
                        env.put_blocking(g.with_unit(1), &buf).unwrap();
                        s_dart_b.push(t.elapsed().as_nanos() as f64);
                    }
                    // DART non-blocking put DTIT
                    let mut s_dart_nb = Samples::new();
                    let mut handles = Vec::with_capacity(REPS);
                    for _ in 0..REPS {
                        let t = Instant::now();
                        let h = env.put(g.with_unit(1), &buf).unwrap();
                        s_dart_nb.push(t.elapsed().as_nanos() as f64);
                        handles.push(h);
                    }
                    env.waitall(handles).unwrap();
                    rows.lock().unwrap().push((size, s_dart_b.median(), s_dart_nb.median()));
                }
                env.barrier(DART_TEAM_ALL).unwrap();
            }
            env.team_memfree(DART_TEAM_ALL, g).unwrap();
        })?;

        // Raw mpisim side (same worlds, windows directly).
        let mpi_rows = Mutex::new(Vec::new());
        let pin2 = match tier {
            Tier::IntraNuma => PinPolicy::Block,
            Tier::InterNuma => PinPolicy::ScatterNuma,
            Tier::InterNode => PinPolicy::ScatterNode,
        };
        let mut wcfg = dart::mpisim::WorldConfig::hermit(2, 2);
        wcfg.pin = pin2;
        dart::mpisim::World::run(wcfg, |mpi| {
            let comm = mpi.comm_world();
            let win = dart::mpisim::Win::allocate(&comm, 1 << 21).unwrap();
            win.lock_all().unwrap();
            for pow in [0usize, 6, 10, 12, 14, 17, 21] {
                let size = 1usize << pow;
                let buf = vec![0xA5u8; size];
                comm.barrier().unwrap();
                if comm.rank() == 0 {
                    let mut s_b = Samples::new();
                    for _ in 0..REPS {
                        let t = Instant::now();
                        win.put(&buf, 1, 0).unwrap();
                        win.flush(1).unwrap();
                        s_b.push(t.elapsed().as_nanos() as f64);
                    }
                    let mut s_nb = Samples::new();
                    let mut reqs = Vec::with_capacity(REPS);
                    for _ in 0..REPS {
                        let t = Instant::now();
                        let r = win.rput(&buf, 1, 0).unwrap();
                        s_nb.push(t.elapsed().as_nanos() as f64);
                        reqs.push(r);
                    }
                    dart::mpisim::RmaRequest::waitall(reqs);
                    mpi_rows.lock().unwrap().push((size, s_b.median(), s_nb.median()));
                }
                comm.barrier().unwrap();
            }
            win.unlock_all().unwrap();
        });

        let rows = rows.into_inner().unwrap();
        let mpi_rows = mpi_rows.into_inner().unwrap();
        for ((size, db, dnb), (_, mb, mnb)) in rows.iter().zip(&mpi_rows) {
            println!(
                "{:>10} {:>14} {:>14} {:>14} {:>14}",
                size,
                fmt_ns(*db),
                fmt_ns(*mb),
                fmt_ns(*dnb),
                fmt_ns(*mnb)
            );
        }
    }
    println!("\npingpong OK (full sweeps: cargo bench)");
    Ok(())
}
