//! A distributed task farm on `dash::WorkQueue` — the dynamic-memory
//! counterpart of the `prodcons` example. Where `prodcons` serializes a
//! single ring behind the MCS lock, here *every* unit owns a lock-free
//! MPMC ring in dynamically attached global memory (`memattach` — no
//! pool budget), enqueues claim slots with `compare_and_swap` tickets,
//! and a consumer whose own ring runs dry **steals** from its
//! neighbours' rings round-robin. No locks anywhere.
//!
//! ```sh
//! cargo run --release --example work_queue [units] [tasks-per-unit]
//! ```
//!
//! Each unit produces `tasks-per-unit` tagged tasks into its own ring;
//! the ring (32 slots) is deliberately smaller than the batch, so a
//! producer that finds it full retires one task itself to make room —
//! producers are consumers too. After a barrier the farm drains: `pop`
//! empties the local ring, then steals. Because every task is claimed by
//! exactly one winning CAS, the allreduced sum of what everyone retired
//! must equal the produced sum exactly — asserted at the end.
//!
//! The full-sized version of this shape (skewed producers, atomic
//! retire counter + XOR checksum against a sequential reference, chaos
//! sweep) lives in `apps/wqueue.rs` and the `perf_dynamic` bench.

use dart::dart::{run, DartConfig, DART_TEAM_ALL};
use dart::dash::WorkQueue;
use dart::mpisim::MpiOp;
use std::sync::atomic::{AtomicU64, Ordering};

const RING: usize = 32; // slots per unit — smaller than the batch on purpose

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let units: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let per_unit: u64 = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(64);
    println!(
        "== PGAS work-stealing farm: {units} units × {per_unit} tasks, rings of {RING} =="
    );

    let retired_sum = AtomicU64::new(0);
    let steals = AtomicU64::new(0);

    run(DartConfig::with_units(units), |env| {
        let me = env.myid() as u64;
        let q = WorkQueue::new(env, DART_TEAM_ALL, RING).unwrap();

        // Produce into my own ring; on full, retire one task myself.
        let mut my_sum = 0u64;
        for k in 0..per_unit {
            let task = me * 1_000_000 + k;
            while !q.push(task).unwrap() {
                if let Some(t) = q.pop().unwrap() {
                    my_sum = my_sum.wrapping_add(t);
                }
            }
        }
        env.barrier(DART_TEAM_ALL).unwrap();

        // Drain: own ring first, then round-robin steals — `pop` does both.
        // Nothing is pushed after the barrier, so a full scan coming back
        // empty means every task has been claimed by someone.
        while let Some(t) = q.pop().unwrap() {
            my_sum = my_sum.wrapping_add(t);
        }

        // Exactly-once oracle: the team-wide retired sum is the produced sum.
        let mut total = [0u64];
        env.allreduce(DART_TEAM_ALL, &[my_sum], &mut total, MpiOp::Sum).unwrap();
        let mut stolen = [0u64];
        env.allreduce(
            DART_TEAM_ALL,
            &[env.metrics.wq_steals.get()],
            &mut stolen,
            MpiOp::Sum,
        )
        .unwrap();
        if env.myid() == 0 {
            retired_sum.store(total[0], Ordering::SeqCst);
            steals.store(stolen[0], Ordering::SeqCst);
        }
        q.free().unwrap();
    })?;

    let produced: u64 = (0..units as u64)
        .map(|u| (0..per_unit).map(|k| u * 1_000_000 + k).sum::<u64>())
        .sum();
    let retired = retired_sum.load(Ordering::SeqCst);
    println!(
        "produced sum = {produced}, retired sum = {retired} ({} cross-ring steals)",
        steals.load(Ordering::SeqCst)
    );
    assert_eq!(produced, retired, "every task retired exactly once");
    println!("work_queue OK ({} tasks through {units} lock-free rings)", units as u64 * per_unit);
    Ok(())
}
