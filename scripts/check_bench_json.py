#!/usr/bin/env python3
"""Validate the schema of the BENCH_*.json files the perf benches emit.

Usage:
    python3 scripts/check_bench_json.py BENCH_hotpath.json BENCH_overlap.json BENCH_dash.json

Each bench writes a single JSON object with a "bench" discriminator; this
script knows the required keys per bench and fails (exit 1) on anything
missing, empty, or non-numeric where a number is expected — so CI catches
a bench silently dropping a field before a perf-trajectory consumer does.
"""

import json
import sys

# bench name -> required top-level keys, result-row location, required row
# keys (split into numeric — which must hold finite numbers — and other).
SCHEMAS = {
    "perf_hotpath": {
        "top": ["bench", "reps", "unit", "results"],
        # results is a dict of named shots
        "rows": lambda doc: list(doc["results"].values()),
        "numeric_keys": ["put_blocking_ns", "get_blocking_ns", "put_dtit_ns"],
        "other_keys": ["requests", "segment_cache"],
    },
    "perf_overlap": {
        "top": ["bench", "reps", "put_bytes", "puts_per_rep", "results"],
        "rows": lambda doc: doc["results"],
        "numeric_keys": [
            "async_bytes",
            "overlap_bytes",
            "overlap_efficiency",
            "flush_ns",
            "coll_wait_ns",
            "engine_ticks",
            "tick_ns_charged",
        ],
        "other_keys": ["mode", "placement", "faults"],
    },
    "perf_dash": {
        "top": ["bench", "units", "reps", "elem_bytes", "results"],
        "rows": lambda doc: doc["results"],
        "numeric_keys": [
            "n",
            "coalesced_runs",
            "redist_bytes",
            "overlap_bytes",
            "copy_ns",
            "bandwidth_mb_s",
            "ops_per_element",
        ],
        "other_keys": ["pattern"],
    },
    "perf_locality": {
        "top": ["bench", "units", "reps", "results"],
        "rows": lambda doc: doc["results"],
        "numeric_keys": [
            "units",
            "reps",
            "ns",
            "intra_ops",
            "inter_ops",
            "fastpath_ops",
            "checksum",
        ],
        "other_keys": ["scenario", "placement", "mode", "faults"],
    },
    "perf_kv": {
        "top": ["bench", "reps", "max_units", "results"],
        "rows": lambda doc: doc["results"],
        "numeric_keys": [
            "units",
            "ops",
            "ops_per_sec",
            "p50_ns",
            "p95_ns",
            "p99_ns",
            "cas_retries",
            "atomic_ops",
            "fastpath_ops",
            "checksum",
            "wall_ms",
        ],
        "other_keys": ["backend", "placement", "exec"],
    },
    "perf_dynamic": {
        "top": ["bench", "reps", "max_units", "results"],
        "rows": lambda doc: doc["results"],
        "numeric_keys": [
            "units",
            "ops",
            "ns_per_op",
            "ops_per_sec",
            "bytes",
            "bandwidth_mb_s",
            "checksum",
            "wall_ms",
        ],
        "other_keys": ["scenario", "placement"],
    },
    "perf_graph": {
        "top": ["bench", "reps", "scale", "edge_factor", "results"],
        "rows": lambda doc: doc["results"],
        "numeric_keys": [
            "units",
            "nverts",
            "nedges",
            "reached",
            "max_level",
            "checksum",
            "rounds",
            "claims",
            "fastpath_atomics",
            "teps",
            "wall_ms",
        ],
        "other_keys": ["mode", "fastpath"],
    },
    "perf_sort": {
        "top": ["bench", "reps", "n", "results"],
        "rows": lambda doc: doc["results"],
        "numeric_keys": [
            "units",
            "n",
            "checksum",
            "position_checksum",
            "max_bucket",
            "redist_ops",
            "keys_per_sec",
            "wall_ms",
        ],
        "other_keys": ["collectives", "fastpath", "dist"],
    },
    "perf_scale": {
        "top": ["bench", "reps", "max_units", "results"],
        "rows": lambda doc: doc["results"],
        "numeric_keys": [
            "units",
            "nodes",
            "reps",
            "ops_per_sec",
            "modelled_ns",
            "wall_ms",
            "node_crossings",
            "active_channels",
            "fastpath_ops",
            "checksum",
        ],
        "other_keys": ["placement", "exec"],
    },
}


def fail(msg: str) -> None:
    print(f"check_bench_json: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def check_file(path: str) -> None:
    try:
        with open(path, encoding="utf-8") as fh:
            doc = json.load(fh)
    except FileNotFoundError:
        fail(f"{path}: file not found (did the bench run?)")
    except json.JSONDecodeError as exc:
        fail(f"{path}: invalid JSON: {exc}")

    if not isinstance(doc, dict):
        fail(f"{path}: top-level JSON value must be an object, got {type(doc).__name__}")

    bench = doc.get("bench")
    schema = SCHEMAS.get(bench)
    if schema is None:
        fail(f"{path}: unknown or missing bench discriminator {bench!r}")

    for key in schema["top"]:
        if key not in doc:
            fail(f"{path}: missing top-level key {key!r}")

    rows = schema["rows"](doc)
    if not rows:
        fail(f"{path}: empty results")
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            fail(f"{path}: results[{i}] is not an object")
        for key in schema["numeric_keys"]:
            if key not in row:
                fail(f"{path}: results[{i}] missing key {key!r}")
            value = row[key]
            if not isinstance(value, (int, float)) or isinstance(value, bool):
                fail(f"{path}: results[{i}].{key} must be a number, got {value!r}")
            if value != value or value in (float("inf"), float("-inf")):
                fail(f"{path}: results[{i}].{key} is not finite")
        for key in schema["other_keys"]:
            if key not in row:
                fail(f"{path}: results[{i}] missing key {key!r}")
    print(f"check_bench_json: OK: {path} ({bench}, {len(rows)} result rows)")


def main() -> None:
    paths = sys.argv[1:]
    if not paths:
        fail("no files given — pass one or more BENCH_*.json paths")
    for path in paths:
        check_file(path)


if __name__ == "__main__":
    main()
