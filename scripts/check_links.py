#!/usr/bin/env python3
"""Markdown link checker for the repo's docs (offline: local targets only).

Usage: python3 scripts/check_links.py README.md docs/*.md

For every inline markdown link `[text](target)`:
- `http(s)://`, `mailto:` and bare-anchor (`#...`) targets are skipped
  (the CI environment is treated as offline);
- every other target is resolved relative to the file containing it
  (dropping any `#fragment`) and must exist.

Exits nonzero listing every broken link.
"""

import re
import sys
from pathlib import Path

# Inline links, skipping images is unnecessary (their paths must exist too).
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def check_file(md: Path) -> list[str]:
    errors = []
    text = md.read_text(encoding="utf-8")
    # Strip fenced code blocks: they hold example output, not links.
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for match in LINK_RE.finditer(text):
        target = match.group(1)
        if target.startswith(SKIP_PREFIXES):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        resolved = (md.parent / path).resolve()
        if not resolved.exists():
            errors.append(f"{md}: broken link -> {target}")
    return errors


def main(argv: list[str]) -> int:
    if len(argv) < 2:
        print(__doc__)
        return 2
    errors = []
    for arg in argv[1:]:
        md = Path(arg)
        if not md.exists():
            errors.append(f"{md}: file not found")
            continue
        errors.extend(check_file(md))
    for e in errors:
        print(e, file=sys.stderr)
    if errors:
        print(f"{len(errors)} broken link(s)", file=sys.stderr)
        return 1
    print(f"checked {len(argv) - 1} file(s): all local links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
