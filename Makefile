# DART-MPI reproduction — build orchestration.
#
# `artifacts/` ships with the repo: the `.meta` sidecars drive the native
# executor (rust/src/runtime/mod.rs), so the Rust stack builds and tests
# offline. `make artifacts` regenerates real HLO text from the JAX/Pallas
# sources when a JAX-capable Python is available.

.PHONY: all build test bench artifacts clean

all: build

build:
	cargo build --release

test:
	cargo test -q

bench:
	DART_BENCH_QUICK=1 cargo bench

artifacts:
	cd python && (python3 -m compile.aot --out-dir ../artifacts || \
		echo "JAX unavailable — keeping the committed .meta catalog (native executor)")

clean:
	cargo clean
